"""Admission scheduler over ``ServingRuntime`` (ISSUE 6).

The contract under test:
  * scheduled results are **bit-exact** vs synchronous ``serve`` of the
    same requests — for coalesced small batches, chunked oversized batches,
    multiple plans on one drain loop, and across a mid-stream ``refresh()``
    (fence: a started request completes entirely on its data generation),
  * SLO flush: a lone request is served within the deadline without
    waiting for a full bucket (auto drain thread),
  * priority lanes are starvation-free both ways — point lookups interleave
    with an in-flight analytical batch, and the batch lane's reserved share
    guarantees progress under an interactive flood,
  * bounded queues reject with ``SchedulerBackpressureError``; closed
    schedulers reject with ``SchedulerClosedError`` (default ``close``
    drains, ``cancel=True`` fails pending futures),
  * normalization errors (ragged / missing / sentinel-valued keys) raise
    synchronously in the submitting caller, not inside the drain loop,
  * the sharded runtime serves through the scheduler bit-exact (8 host
    devices; multi-device CI job).

Deterministic tests drive ``auto_start=False`` schedulers via ``step()``;
only the SLO test relies on the drain thread and wall-clock.
"""
import concurrent.futures
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import LinearOperator
from repro.core.laq import PAD_KEY, Catalog, Table
from repro.core.laq.selection import Pred
from repro.core.query import (Aggregate, AdmissionScheduler, ArmSpec,
                              PREDICTION, PredictiveQuery, ScheduledPlan,
                              SchedulerBackpressureError,
                              SchedulerClosedError, SentinelKeyError,
                              Session, compile_serving)
from repro.launch.mesh import make_serving_mesh

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

BUCKETS = (4, 16)   # top bucket 16 → default batch reserve 4


# --------------------------------------------------------------------- data
def star_catalog(seed: int = 3, n_d1: int = 40, n_d2: int = 12,
                 slack: int = 16) -> Catalog:
    rng = np.random.default_rng(seed)
    d1 = {"pk": np.arange(n_d1) * 2,          # even keys; odd keys = appends
          "a": rng.normal(size=n_d1), "b": rng.normal(size=n_d1)}
    d2 = {"pk2": np.arange(n_d2), "c": rng.normal(size=n_d2)}
    f = {"fk1": rng.integers(0, 2 * n_d1, 8),
         "fk2": rng.integers(0, n_d2, 8), "val": rng.normal(size=8)}
    return Catalog({
        "d1": Table.from_columns("d1", d1, key_cols=("pk",),
                                 capacity=n_d1 + slack),
        "d2": Table.from_columns("d2", d2, key_cols=("pk2",),
                                 capacity=n_d2 + slack),
        "fact": Table.from_columns("fact", f, key_cols=("fk1", "fk2")),
    })


def _query(seed: int = 0) -> PredictiveQuery:
    rng = np.random.default_rng(seed)
    model = LinearOperator(jnp.asarray(
        rng.normal(size=(3, 2)).astype(np.float32)))
    return PredictiveQuery(
        fact="fact",
        arms=(ArmSpec("d1", "fk1", "pk", ("a", "b"),
                      (Pred("a", ">", -1.0),)),
              ArmSpec("d2", "fk2", "pk2", ("c",))),
        model=model,
        aggregates=(Aggregate(PREDICTION, "sum", "pred"),))


def _requests(rng, n, n_d1=40, n_d2=12):
    """Random per-arm FK batch; ~1/8 of keys miss (not-found masking)."""
    return {"fk1": rng.integers(0, int(2 * n_d1 * 9 / 8), n).astype(np.int32),
            "fk2": rng.integers(0, int(n_d2 * 9 / 8), n).astype(np.int32)}


@pytest.fixture()
def rt():
    return compile_serving(star_catalog(), _query(), buckets=BUCKETS)


@pytest.fixture()
def sched():
    s = AdmissionScheduler(auto_start=False)
    yield s
    s.close(cancel=True)


# ----------------------------------------------------------- bit-exactness
def test_coalesced_step_bit_exact_and_counted(rt, sched):
    plan = sched.register(rt, "p")
    rng = np.random.default_rng(0)
    reqs = [_requests(rng, n) for n in (2, 3, 4)]
    futs = [plan.submit(r) for r in reqs]
    assert sched.step() == 9          # one coalesced admission step
    for f, r in zip(futs, reqs):
        np.testing.assert_array_equal(np.asarray(f.result(0)),
                                      np.asarray(rt.serve(r)))
    st = plan.stats()
    assert st["steps"] == 1 and st["admitted_rows"] == 9
    assert st["padded_rows"] == 16 - 9     # padded into the top bucket
    assert st["lanes"]["interactive"]["count"] == 3


def test_oversized_batch_chunks_bit_exact(rt, sched):
    plan = sched.register(rt)
    rng = np.random.default_rng(1)
    reqs = _requests(rng, 3 * BUCKETS[-1] + 5)      # 53 rows → 4 chunks
    fut = plan.submit(reqs, lane="batch")
    steps = 0
    while not fut.done():
        assert sched.step() > 0
        steps += 1
    assert steps == 4
    np.testing.assert_array_equal(np.asarray(fut.result(0)),
                                  np.asarray(rt.serve(reqs)))


def test_multiple_plans_one_drain_loop(sched):
    cat = star_catalog()
    rt_a = compile_serving(cat, _query(0), buckets=BUCKETS)
    rt_b = compile_serving(cat, _query(1), buckets=BUCKETS)
    pa, pb = sched.register(rt_a, "a"), sched.register(rt_b, "b")
    assert sched.plan_names == ("a", "b")
    # Re-registering a runtime is idempotent (same plan handle).
    assert sched.register(rt_a).name == "a"
    rng = np.random.default_rng(2)
    ra, rb = _requests(rng, 7), _requests(rng, 11)
    fa, fb = pa.submit(ra), pb.submit(rb)
    assert sched.step() == 18          # one step per plan, same call
    np.testing.assert_array_equal(np.asarray(fa.result(0)),
                                  np.asarray(rt_a.serve(ra)))
    np.testing.assert_array_equal(np.asarray(fb.result(0)),
                                  np.asarray(rt_b.serve(rb)))


def test_zero_row_submission_resolves_immediately(rt, sched):
    plan = sched.register(rt)
    fut = plan.submit({"fk1": np.zeros(0, np.int32),
                       "fk2": np.zeros(0, np.int32)})
    assert np.asarray(fut.result(0)).shape == (0, rt.out_width)


# ------------------------------------------------------------------- lanes
def test_point_lookups_interleave_with_inflight_analytical(rt, sched):
    plan = sched.register(rt)
    rng = np.random.default_rng(3)
    big = _requests(rng, 4 * BUCKETS[-1])           # 4-step analytical scan
    small = _requests(rng, 2)
    fb = plan.submit(big, lane="batch")
    assert sched.step() == BUCKETS[-1]              # scan starts alone
    fi = plan.submit(small)                         # point lookup arrives
    sched.step()
    # The lookup rode along with the scan's next chunk instead of queueing
    # behind the whole scan.
    assert fi.done() and not fb.done()
    while not fb.done():
        sched.step()
    np.testing.assert_array_equal(np.asarray(fi.result(0)),
                                  np.asarray(rt.serve(small)))
    np.testing.assert_array_equal(np.asarray(fb.result(0)),
                                  np.asarray(rt.serve(big)))


def test_batch_reserve_prevents_interactive_starvation(rt, sched):
    plan = sched.register(rt)
    rng = np.random.default_rng(4)
    scan = _requests(rng, 2 * BUCKETS[-1])          # needs 32 admitted rows
    fb = plan.submit(scan, lane="batch")
    reserve = max(1, BUCKETS[-1] // 4)
    flood_budget = BUCKETS[-1] - reserve
    steps = 0
    while not fb.done():
        # Fill the whole interactive budget before every step: without the
        # reserve the scan would never be admitted a single row.
        flood = plan.submit(_requests(rng, flood_budget))
        sched.step()
        steps += 1
        assert flood.done()                         # interactive first...
        assert steps <= int(np.ceil(2 * BUCKETS[-1] / reserve))
    # ...but the scan still progressed ≥ reserve rows per step.
    np.testing.assert_array_equal(np.asarray(fb.result(0)),
                                  np.asarray(rt.serve(scan)))


def test_unknown_lane_and_plan_are_named_errors(rt, sched):
    plan = sched.register(rt)
    with pytest.raises(ValueError, match="unknown lane"):
        plan.submit(_requests(np.random.default_rng(0), 1), lane="bulk")
    with pytest.raises(KeyError, match="unknown plan"):
        sched.submit("nope", _requests(np.random.default_rng(0), 1))
    with pytest.raises(ValueError, match="already registered"):
        sched.register(compile_serving(star_catalog(), _query(1),
                                       buckets=BUCKETS), plan.name)


# ---------------------------------------------------- backpressure / close
def test_backpressure_rejects_with_named_error(rt):
    s = AdmissionScheduler(auto_start=False, max_queued_rows=8)
    plan = s.register(rt)
    rng = np.random.default_rng(5)
    plan.submit(_requests(rng, 6))
    with pytest.raises(SchedulerBackpressureError, match="at capacity"):
        plan.submit(_requests(rng, 6))
    plan.submit(_requests(rng, 2))                  # exactly at the bound
    assert plan.stats()["rejected"] == 1
    s.step()                                        # admission frees the lane
    plan.submit(_requests(rng, 8))
    s.close()


def test_close_drains_by_default_and_rejects_new_work(rt):
    s = AdmissionScheduler(auto_start=False)
    plan = s.register(rt)
    rng = np.random.default_rng(6)
    reqs = _requests(rng, 3)
    fut = plan.submit(reqs)
    s.close()                                       # drains queued work
    np.testing.assert_array_equal(np.asarray(fut.result(0)),
                                  np.asarray(rt.serve(reqs)))
    with pytest.raises(SchedulerClosedError):
        plan.submit(reqs)
    with pytest.raises(SchedulerClosedError):
        s.register(compile_serving(star_catalog(), _query(1),
                                   buckets=BUCKETS))


def test_close_cancel_fails_pending_futures(rt):
    s = AdmissionScheduler(auto_start=False)
    plan = s.register(rt)
    fut = plan.submit(_requests(np.random.default_rng(7), 3))
    s.close(cancel=True)
    with pytest.raises(SchedulerClosedError):
        fut.result(0)


def test_cancelled_future_is_dropped_at_admission(rt, sched):
    plan = sched.register(rt)
    rng = np.random.default_rng(8)
    f1, keep = plan.submit(_requests(rng, 3)), _requests(rng, 2)
    f2 = plan.submit(keep)
    assert f1.cancel()
    assert sched.step() == 2                        # only the live request
    np.testing.assert_array_equal(np.asarray(f2.result(0)),
                                  np.asarray(rt.serve(keep)))


# ------------------------------------------------- synchronous validation
def test_normalization_errors_raise_in_submitting_caller(rt, sched):
    plan = sched.register(rt)
    with pytest.raises(SentinelKeyError, match="padding sentinel"):
        plan.submit({"fk1": np.array([3, PAD_KEY], np.int32),
                     "fk2": np.array([1, 2], np.int32)})
    with pytest.raises(ValueError, match="ragged"):
        plan.submit({"fk1": np.array([3, 4], np.int32),
                     "fk2": np.array([1], np.int32)})
    with pytest.raises(KeyError):
        plan.submit({"fk1": np.array([3], np.int32)})
    assert sched.step() == 0                        # nothing was enqueued


def test_step_requires_manual_mode(rt):
    s = AdmissionScheduler()
    try:
        with pytest.raises(RuntimeError, match="auto_start=False"):
            s.step()
    finally:
        s.close()


# ------------------------------------------------------------ SLO (timed)
def test_slo_flushes_lone_request_without_full_bucket(rt):
    with AdmissionScheduler(slo_ms=5.0) as s:
        plan = s.register(rt)
        rng = np.random.default_rng(9)
        reqs = _requests(rng, 2)                    # far below the bucket
        t0 = time.perf_counter()
        fut = plan.submit(reqs)
        out = np.asarray(fut.result(timeout=30))
        waited = time.perf_counter() - t0
        np.testing.assert_array_equal(out, np.asarray(rt.serve(reqs)))
        # Generous bound (CI wall-clock): flushed by the deadline, not
        # held forever waiting for 16 rows.
        assert waited < 10.0
        st = plan.stats()["lanes"]["interactive"]
        assert st["count"] == 1 and st["p50"] >= 0.0


# -------------------------------------------------------- refresh fencing
def test_refresh_fence_keeps_request_on_one_generation():
    cat = star_catalog()
    q = _query()
    rt = compile_serving(cat, q, buckets=BUCKETS)
    twin = compile_serving(cat, q, buckets=BUCKETS)
    rng = np.random.default_rng(10)
    # Batch whose keys include rows that only exist AFTER the append (odd
    # d1 keys): old and new generations give different answers for it.
    reqs = {"fk1": np.concatenate([
                rng.integers(0, 80, 40), 81 + 2 * np.arange(8)]
            ).astype(np.int32),
            "fk2": rng.integers(0, 12, 48).astype(np.int32)}
    want_old = np.asarray(twin.serve(reqs))

    s = AdmissionScheduler(auto_start=False)
    plan = s.register(rt)
    fut = plan.submit(reqs, lane="batch")
    assert s.step() == BUCKETS[-1]                  # mid-flight: 16/48 rows
    cat.append("d1", {"pk": 81 + 2 * np.arange(8),
                      "a": rng.normal(size=8), "b": rng.normal(size=8)})
    # Drain-then-swap: the started request finishes on the old state.
    decisions = s.refresh(rt)
    assert fut.done()
    np.testing.assert_array_equal(np.asarray(fut.result(0)), want_old)
    assert "delta" in decisions[plan.name] or "no-op" in decisions[plan.name]

    # Post-swap requests see the new generation (== refreshed twin).
    twin.refresh()
    want_new = np.asarray(twin.serve(reqs))
    assert not np.array_equal(want_old, want_new)   # the append matters
    f2 = plan.submit(reqs)
    while not f2.done():
        s.step()
    np.testing.assert_array_equal(np.asarray(f2.result(0)), want_new)
    s.close()


def test_session_routes_cached_runtime_refresh_through_fence():
    cat = star_catalog()
    q = _query()
    sess = Session(cat)
    plan = sess.bind(q).serve(buckets=BUCKETS, async_=True)
    assert isinstance(plan, ScheduledPlan)
    assert sess.bind(q).serve(buckets=BUCKETS, async_=True).name == plan.name
    rng = np.random.default_rng(11)
    reqs = _requests(rng, 6)
    np.testing.assert_array_equal(
        np.asarray(plan.submit(reqs).result(30)),
        np.asarray(compile_serving(cat, q, buckets=BUCKETS).serve(reqs)))
    cat.append("d1", {"pk": 81 + 2 * np.arange(4),
                      "a": rng.normal(size=4), "b": rng.normal(size=4)})
    # The cached-runtime hit path must fence through the scheduler, not
    # call runtime.refresh() under the drain thread.
    rt2 = sess.bind(q).serve(buckets=BUCKETS)
    assert rt2 is plan.runtime
    new_keys = {"fk1": (81 + 2 * np.arange(4)).astype(np.int32),
                "fk2": np.arange(4).astype(np.int32)}
    got = np.asarray(plan.submit(new_keys).result(30))
    twin = compile_serving(cat, q, buckets=BUCKETS)
    np.testing.assert_array_equal(got, np.asarray(twin.serve(new_keys)))
    with pytest.raises(ValueError, match="already running"):
        sess.scheduler(slo_ms=1.0)
    sess.scheduler().close()
    # A closed session scheduler is replaced lazily on next use.
    assert sess.scheduler(slo_ms=1.0).slo_ms == 1.0
    sess.scheduler().close()


# ------------------------------------------------------- concurrent load
def test_concurrent_submitters_all_bit_exact(rt):
    """Many threads submit through the drain thread; every result exact."""
    rng = np.random.default_rng(12)
    batches = [_requests(rng, int(n)) for n in rng.integers(1, 40, 24)]
    want = [np.asarray(rt.serve(b)) for b in batches]
    with AdmissionScheduler(slo_ms=1.0) as s:
        plan = s.register(rt)
        with concurrent.futures.ThreadPoolExecutor(4) as pool:
            futs = list(pool.map(
                lambda b: plan.submit(b, lane="batch"
                                      if b["fk1"].size > 20 else
                                      "interactive"),
                batches))
            got = [np.asarray(f.result(60)) for f in futs]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


# ------------------------------------------------------------ sharded (CI)
@needs_8_devices
def test_sharded_runtime_through_scheduler_bit_exact():
    mesh = make_serving_mesh((1, 8))
    cat = star_catalog()
    q = _query()
    ref = compile_serving(cat, q, buckets=BUCKETS)
    rt = compile_serving(cat, q, buckets=BUCKETS, mesh=mesh,
                         shard_threshold_bytes=0)
    assert rt.sharded
    s = AdmissionScheduler(auto_start=False)
    plan = s.register(rt)
    rng = np.random.default_rng(13)
    reqs = [_requests(rng, n) for n in (3, 16, 40)]   # incl. chunked
    futs = [plan.submit(r, lane="batch" if r["fk1"].size > 16 else
                        "interactive") for r in reqs]
    while not all(f.done() for f in futs):
        s.step()
    for f, r in zip(futs, reqs):
        np.testing.assert_array_equal(np.asarray(f.result(0)),
                                      np.asarray(ref.serve(r)))
    s.close()
