"""Operator-fusion correctness: fused == non-fused, GEMM tree == traversal."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st

from repro.core.fusion import (LinearOperator, plan_fusion,
                               predict_fused, predict_fused_matmul,
                               predict_nonfused, predict_nonfused_matmul,
                               prefuse, random_tree, reference_tree_eval,
                               tree_from_arrays)
from repro.core.laq import DimSpec, Table, star_join


def make_star(rng, n_fact=40, dims_shape=((8, 3), (6, 2), (5, 3)),
              miss_keys=True):
    specs, fact_cols = [], {}
    for d, (n_dim, ncols) in enumerate(dims_shape):
        pk = rng.permutation(n_dim * 2)[:n_dim].astype(np.int32)
        cols = {f"f{j}": rng.normal(size=n_dim).astype(np.float32)
                for j in range(ncols)}
        cols["pk"] = pk
        dim = Table.from_columns(f"dim{d}", cols, key_cols=("pk",))
        pool = np.concatenate([pk, [999]]) if miss_keys else pk
        fact_cols[f"fk{d}"] = rng.choice(pool, size=n_fact)
        specs.append(DimSpec(dim, f"fk{d}", "pk",
                             tuple(f"f{j}" for j in range(ncols))))
    fact = Table.from_columns(
        "fact", fact_cols, key_cols=tuple(fact_cols.keys()))
    return star_join(fact, specs)


# ------------------------------------------------------------ linear fusion
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 7))
def test_linear_fusion_equals_nonfused(seed, l):
    rng = np.random.default_rng(seed)
    sj = make_star(rng)
    k = sj.feature_width
    model = LinearOperator(jnp.asarray(rng.normal(size=(k, l)), jnp.float32))
    non = np.asarray(predict_nonfused(sj, model))
    pre = prefuse(sj, model)
    fus = np.asarray(predict_fused(sj, pre))
    np.testing.assert_allclose(fus, non, rtol=1e-4, atol=1e-5)
    # Paper-faithful dense-matmul paths agree too.
    fus_mm = np.asarray(predict_fused_matmul(sj, pre))
    non_mm = np.asarray(predict_nonfused_matmul(sj, model))
    np.testing.assert_allclose(fus_mm, non, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(non_mm, non, rtol=1e-4, atol=1e-5)


def test_linear_compose_associativity():
    rng = np.random.default_rng(0)
    a = LinearOperator(jnp.asarray(rng.normal(size=(6, 4)), jnp.float32))
    b = LinearOperator(jnp.asarray(rng.normal(size=(4, 2)), jnp.float32))
    x = jnp.asarray(rng.normal(size=(9, 6)), jnp.float32)
    np.testing.assert_allclose(np.asarray(a.compose(b).apply(x)),
                               np.asarray(b.apply(a.apply(x))), rtol=1e-5)


# ------------------------------------------------------------- GEMM tree
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 4), st.integers(2, 9))
def test_tree_gemm_matches_traversal(seed, depth, k):
    rng = np.random.default_rng(seed)
    p = 2**depth - 1
    feature = rng.integers(0, k, size=p)
    threshold = rng.normal(size=p).astype(np.float32)
    tree = tree_from_arrays(feature, threshold, k)
    x = rng.normal(size=(32, k)).astype(np.float32)
    onehot = np.asarray(tree.apply(jnp.asarray(x)))
    # Exactly one leaf per row.
    np.testing.assert_array_equal(onehot.sum(axis=1), np.ones(32))
    got = onehot.argmax(axis=1)
    want = reference_tree_eval(feature, threshold, x)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 4))
def test_tree_fusion_equals_nonfused(seed, depth):
    rng = np.random.default_rng(seed)
    sj = make_star(rng)
    k = sj.feature_width
    tree = random_tree(rng, k, depth)
    non = np.asarray(predict_nonfused(sj, tree))
    pre = prefuse(sj, tree)
    fus = np.asarray(predict_fused(sj, pre))
    valid = np.asarray(sj.row_valid)
    # Identical one-hot predictions on valid rows; zeros elsewhere.
    np.testing.assert_array_equal(fus[valid], non[valid])
    assert np.all(fus[~valid] == 0)
    fus_mm = np.asarray(predict_fused_matmul(sj, pre))
    np.testing.assert_array_equal(fus_mm[valid], non[valid])


def test_tree_fusion_partial_predicates_are_masked():
    """A dim must not contribute predicate bits for nodes it doesn't own."""
    rng = np.random.default_rng(42)
    sj = make_star(rng, n_fact=20)
    # Thresholds strongly negative so (0 > v) would spuriously fire if
    # ownership masking were missing.
    k = sj.feature_width
    p = 7
    feature = rng.integers(0, k, size=p)
    threshold = -np.abs(rng.normal(size=p)).astype(np.float32) - 5.0
    tree = tree_from_arrays(feature, threshold, k)
    non = np.asarray(predict_nonfused(sj, tree))
    fus = np.asarray(predict_fused(sj, prefuse(sj, tree)))
    valid = np.asarray(sj.row_valid)
    np.testing.assert_array_equal(fus[valid], non[valid])


# --------------------------------------------------------------- planner
def test_planner_prefers_fusion_for_narrow_models():
    lin = LinearOperator(jnp.zeros((128, 1), jnp.float32))
    d = plan_fusion(lin, fact_rows=600_000, dim_rows=[20_000, 2_000, 2_555])
    assert d.fuse and d.est_speedup > 10


def test_planner_rejects_fusion_when_never_amortized():
    lin = LinearOperator(jnp.zeros((16, 2048), jnp.float32))
    d = plan_fusion(lin, fact_rows=3_000, dim_rows=[2_000, 2_000, 2_555],
                    batches_per_update=1e-3)
    assert not d.fuse


def test_planner_memory_budget():
    lin = LinearOperator(jnp.zeros((128, 1024), jnp.float32))
    d = plan_fusion(lin, fact_rows=600_000, dim_rows=[1_000_000],
                    memory_budget_bytes=1024)
    assert not d.fuse and "budget" in d.reason
