"""Dynamic-batch serving runtime vs the compiled-query serving path.

The contract under test (ISSUE 2 acceptance):
  * one compiled plan serves request batches of many sizes with no
    recompilation beyond the fixed bucket set (asserted via trace and jit
    cache counts),
  * the Pallas kernel backend matches the jnp gather backend bit-exactly
    in fp32 on the full predictive-query suite,
  * serving the FKs of fact rows reproduces ``CompiledQuery.predict_rows``
    bit-exactly for rows that pass the fact-side predicates.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import DecisionTreeGEMM, LinearOperator
from repro.core.query import (
    compile_query,
    compile_serving,
    plan_serving_backend,
    requests_from_rows,
)
from repro.core.query.planner import resolve_serve_backend
from repro.data import QUERY_IR, generate_ssb, predictive_query_names, ssb_catalog

PRED_NAMES = predictive_query_names()
BUCKETS = (8, 32, 128)


@pytest.fixture(scope="module")
def data():
    return generate_ssb(sf=1, scale=0.0005, seed=5)


@pytest.fixture(scope="module")
def catalog(data):
    return ssb_catalog(data)


@pytest.fixture(scope="module")
def plans():
    """Per-module cache: (name, kwargs) -> compiled plan or runtime."""
    return {}


def _compiled(plans, catalog, name, **kwargs):
    key = ("query", name, tuple(sorted(kwargs.items())))
    if key not in plans:
        plans[key] = compile_query(catalog, QUERY_IR[name](), **kwargs)
    return plans[key]


def _runtime(plans, catalog, name, **kwargs):
    kwargs.setdefault("buckets", BUCKETS)
    key = ("serve", name, tuple(sorted(kwargs.items())))
    if key not in plans:
        plans[key] = compile_serving(catalog, QUERY_IR[name](), **kwargs)
    return plans[key]


def _passing_rows(catalog, q):
    """Fact rows on which serving and predict_rows must agree exactly."""
    fact = catalog[q.fact]
    ok = np.asarray(fact.valid_mask())
    for p in q.fact_preds:
        ok = ok & np.asarray(p.mask(fact))
    return np.nonzero(ok)[0]


def _random_requests(q, catalog, n, rng):
    """Random FK batches: live dimension keys mixed with guaranteed misses."""
    reqs = {}
    for arm in q.arms:
        dim = catalog[arm.table]
        live = np.asarray(dim.key(arm.pk_col))[: int(dim.nvalid)]
        keys = rng.choice(live, size=n)
        miss = rng.random(n) < 0.25
        keys = np.where(miss, rng.integers(-3, 0, size=n), keys)
        reqs[arm.fk_col] = keys.astype(np.int32)
    return reqs


# ------------------------------------------------ serving ≡ predict_rows
@pytest.mark.parametrize("backend", ["fused", "nonfused"])
@pytest.mark.parametrize("name", PRED_NAMES)
def test_serving_matches_predict_rows(name, backend, catalog, plans):
    q = QUERY_IR[name]()
    compiled = _compiled(plans, catalog, name, backend=backend)
    runtime = _runtime(plans, catalog, name, backend=backend)
    ids = _passing_rows(catalog, q)[:50]
    got = np.asarray(runtime.serve(requests_from_rows(catalog[q.fact], q, ids)))
    want = np.asarray(compiled.predict_rows(jnp.asarray(ids, jnp.int32)))
    np.testing.assert_array_equal(got, want)


# ------------------------------------- compile once, serve any batch size
def test_one_plan_serves_ragged_batches_without_recompile(catalog, plans):
    q = QUERY_IR["P1.linear.year"]()
    runtime = compile_serving(catalog, q, buckets=BUCKETS)
    rng = np.random.default_rng(0)
    sizes = [1, 3, 8, 9, 31, 32, 33, 100, 128]
    for n in sizes:
        out = runtime.serve(_random_requests(q, catalog, n, rng))
        assert out.shape == (n, runtime.out_width)
    assert runtime.num_compiles == len(BUCKETS)
    cache = runtime.jit_cache_size()
    if cache is not None:
        assert cache == len(BUCKETS)
    # A second ragged sweep plus oversized (chunked) batches: still no
    # recompilation beyond the fixed bucket set.
    for n in sizes + [129, 300, 1000]:
        runtime.serve(_random_requests(q, catalog, n, rng))
    assert runtime.num_compiles == len(BUCKETS)
    stats = runtime.latency_stats()
    # Chunked oversized calls report under their own key: their wall time
    # covers the whole request, not one top-bucket dispatch, so mixing it
    # into the top bucket's window would corrupt point-lookup percentiles.
    assert set(stats) == set(BUCKETS) | {"chunked"}
    assert all(s["count"] > 0 for s in stats.values())
    assert all(s["p50"] <= s["p99"] for s in stats.values())
    assert all("compile_ms" in s for b, s in stats.items() if b != "chunked")
    assert stats["chunked"]["count"] == 3          # 129, 300, 1000
    assert all(s["count"] == 5 for b, s in stats.items() if b != "chunked"), \
        "per-chunk dispatches must not inflate the top bucket's window"


def test_empty_batch_and_request_validation(catalog, plans):
    q = QUERY_IR["P1.linear.year"]()
    runtime = _runtime(plans, catalog, "P1.linear.year", backend="fused")
    empty = runtime.serve({k: np.zeros(0, np.int32) for k in runtime.request_keys})
    assert empty.shape == (0, runtime.out_width)
    with pytest.raises(KeyError):
        runtime.serve({"nope": np.zeros(4, np.int32)})
    ragged = [np.zeros(4, np.int32), np.zeros(5, np.int32), np.zeros(4, np.int32)]
    with pytest.raises(ValueError):
        runtime.serve(ragged)
    with pytest.raises(ValueError):
        compile_serving(catalog, QUERY_IR["Q1.1"]())
    with pytest.raises(ValueError):
        compile_serving(catalog, q, serve_backend="bogus")
    with pytest.raises(ValueError):
        compile_serving(catalog, q, buckets=())


# ------------------------------------------- Pallas kernel ≡ jnp gathers
@pytest.mark.parametrize("name", PRED_NAMES)
def test_kernel_backend_bitexact_full_pred_suite(name, catalog, plans):
    """fused_star_gather lowering ≡ jnp gather backend, bitwise in fp32."""
    q = QUERY_IR[name]()
    rng = np.random.default_rng(7)
    ref = _runtime(plans, catalog, name, backend="fused", serve_backend="jnp")
    ker = _runtime(
        plans,
        catalog,
        name,
        backend="fused",
        serve_backend="pallas",
        interpret=True,
    )
    assert ker.serve_backend == "pallas"
    for n in (5, 32, 64):
        reqs = _random_requests(q, catalog, n, rng)
        np.testing.assert_array_equal(
            np.asarray(ker.serve(reqs)),
            np.asarray(ref.serve(reqs)),
        )


@pytest.mark.parametrize("name", ["P3.tree.year", "P4.tree.select.region"])
def test_tree_predict_kernel_bitexact_nonfused(name, catalog, plans):
    """Non-fused tree serving lowers onto tree_predict, bit-exactly."""
    q = QUERY_IR[name]()
    rng = np.random.default_rng(8)
    ref = _runtime(plans, catalog, name, backend="nonfused", serve_backend="jnp")
    ker = _runtime(
        plans,
        catalog,
        name,
        backend="nonfused",
        serve_backend="pallas",
        interpret=True,
    )
    reqs = _random_requests(q, catalog, 40, rng)
    np.testing.assert_array_equal(
        np.asarray(ker.serve(reqs)),
        np.asarray(ref.serve(reqs)),
    )


def test_compile_query_pallas_serve_backend(catalog, plans):
    """compile_query's own serving path accepts the kernel lowering too."""
    name = "P2.linear.select.scalar"
    jnp_plan = _compiled(plans, catalog, name, backend="fused")
    ker_plan = _compiled(
        plans,
        catalog,
        name,
        backend="fused",
        serve_backend="pallas",
        interpret=True,
    )
    assert ker_plan.serve_backend == "pallas"
    assert ker_plan.plan.serve_backend == "pallas"
    ids = jnp.asarray([0, 1, 5, 17, 100, 2999], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ker_plan.predict_rows(ids)),
        np.asarray(jnp_plan.predict_rows(ids)),
    )


def test_compile_query_pallas_nonfused_tree(catalog, plans):
    """Non-fused trees lower onto tree_predict; non-fused linear clamps to
    jnp so serve_backend always names the kernel that actually runs."""
    jnp_plan = _compiled(plans, catalog, "P3.tree.year", backend="nonfused")
    ker_plan = _compiled(
        plans,
        catalog,
        "P3.tree.year",
        backend="nonfused",
        serve_backend="pallas",
        interpret=True,
    )
    assert ker_plan.serve_backend == "pallas"
    assert ker_plan.plan.serve_backend == "pallas"
    ids = jnp.asarray([0, 2, 9, 41, 333], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ker_plan.predict_rows(ids)),
        np.asarray(jnp_plan.predict_rows(ids)),
    )
    clamped = _compiled(
        plans,
        catalog,
        "P1.linear.year",
        backend="nonfused",
        serve_backend="pallas",
        interpret=True,
    )
    assert clamped.serve_backend == "jnp"
    assert clamped.plan.serve_backend == "jnp"


# ----------------------------------------------------- planner choices
def test_plan_serving_backend_rules():
    rng = np.random.default_rng(0)
    linear = LinearOperator(jnp.asarray(rng.normal(size=(6, 4)), jnp.float32))
    assert plan_serving_backend(linear, 3, platform="cpu")[0] == "jnp"
    assert plan_serving_backend(linear, 3, platform="tpu")[0] == "pallas"
    assert plan_serving_backend(None, 3, platform="tpu")[0] == "jnp"
    got = plan_serving_backend(linear, 3, backend="nonfused", platform="tpu")
    assert got[0] == "jnp"
    from repro.core.fusion import random_tree

    tree = random_tree(rng, 6, 2)
    assert isinstance(tree, DecisionTreeGEMM)
    got = plan_serving_backend(tree, 3, backend="nonfused", platform="tpu")
    assert got[0] == "pallas"
    # resolve_serve_backend: only nonfused linear lacks a kernel lowering.
    assert resolve_serve_backend("pallas", "fused", linear) == "pallas"
    assert resolve_serve_backend("pallas", "nonfused", linear) == "jnp"
    assert resolve_serve_backend("pallas", "nonfused", tree) == "pallas"
    assert resolve_serve_backend("jnp", "fused", linear) == "jnp"
