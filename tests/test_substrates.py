"""Substrate tests: optimizer, compression, checkpointing, data, runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import (QUERIES, TokenPipeline, TokenPipelineConfig,
                        generate_ssb, generate_star)
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress,
                         compress_tree, decompress, warmup_cosine)
from repro.runtime import (HeartbeatMonitor, SimulatedCluster,
                           StragglerMonitor, elastic_remesh)


# ---------------------------------------------------------------- optim ----
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray([2.0])}
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for i in range(200):
        g = jax.grad(loss)(params)
        sc = warmup_cosine(i, 10, 200)
        params, state, metrics = adamw_update(params, g, state, cfg, sc)
    assert float(loss(params)) < 1e-2
    assert np.isfinite(float(metrics["grad_norm"]))


def test_adamw_bf16_state_dtype():
    cfg = AdamWConfig(lr=0.1, state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.5, jnp.bfloat16)}
    p2, s2, _ = adamw_update(params, g, state, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert float(jnp.abs(p2["w"] - params["w"]).max()) > 0


# ---------------------------------------------------------- compression ----
def test_compress_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    c, residual = compress(x)
    xh = decompress(c)
    assert c.q.dtype == jnp.int8
    # Block int8: ~1% relative error on N(0,1).
    err = np.abs(np.asarray(xh) - np.asarray(x)).max()
    assert err < 0.05
    np.testing.assert_allclose(np.asarray(x - xh), np.asarray(residual),
                               atol=1e-6)


def test_error_feedback_preserves_mean_update():
    """Error feedback: accumulated compressed grads ≈ accumulated true."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros((64,), np.float32)
    comp_sum = np.zeros((64,), np.float32)
    res = None
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
        true_sum += np.asarray(g["w"])
        ghat, res = compress_tree(g, res)
        comp_sum += np.asarray(ghat["w"])
    # Residual carries over; cumulative drift bounded by one quant step.
    np.testing.assert_allclose(comp_sum, true_sum, atol=0.1)


# ------------------------------------------------------------ checkpoint ---
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree),
                 extras={"step": step})
    assert mgr.all_steps() == [2, 3]  # retention dropped step 1
    restored, extras = mgr.restore(3, tree)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(12.0).reshape(3, 4) * 3)
    assert extras["step"] == 3


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.ones((128, 64))}
    mgr.save_async(10, tree, extras={"loss": 1.5})
    mgr.wait()
    assert mgr.latest_step() == 10
    # A partial (uncommitted) dir is ignored.
    os.makedirs(tmp_path / "step_00000011")
    assert mgr.latest_step() == 10


def test_checkpoint_restore_into_new_sharding(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jax.device_put(jnp.arange(16.0).reshape(4, 4),
                                NamedSharding(mesh, P("data", None)))}
    mgr.save(1, tree)
    target = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    restored, _ = mgr.restore(
        1, target, sharding_fn=lambda p: NamedSharding(mesh, P(None, "data")))
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(16.0).reshape(4, 4))


# ----------------------------------------------------------------- data ----
def test_token_pipeline_deterministic_and_restorable():
    cfg = TokenPipelineConfig(vocab_size=100, global_batch=4, seq_len=16)
    p1 = TokenPipeline(cfg, process_index=0, process_count=1)
    a_tok, a_lab = p1.next()
    b_tok, _ = p1.next()
    assert a_tok.shape == (4, 16)
    np.testing.assert_array_equal(a_tok[:, 1:], a_lab[:, :-1])
    # Restore to step 0 replays identically.
    p2 = TokenPipeline(cfg, process_index=0, process_count=1)
    p2.restore({"step": 0, "seed": 0})
    np.testing.assert_array_equal(p2.next()[0], a_tok)
    np.testing.assert_array_equal(p2.next()[0], b_tok)
    assert not np.array_equal(a_tok, b_tok)


def test_token_pipeline_host_slices_disjoint_and_prefetch():
    cfg = TokenPipelineConfig(vocab_size=50, global_batch=8, seq_len=8)
    h0 = TokenPipeline(cfg, process_index=0, process_count=2)
    h1 = TokenPipeline(cfg, process_index=1, process_count=2)
    h0.start()
    t0, _ = h0.next()
    t1, _ = h1.next()
    h0.stop()
    assert t0.shape == (4, 8) and t1.shape == (4, 8)
    assert not np.array_equal(t0, t1)


def test_ssb_generator_and_query_sanity():
    data = generate_ssb(sf=1, scale=0.002, seed=0)
    res = QUERIES["Q1.1"](data)
    assert float(res["rows"]) > 0
    assert np.isfinite(float(res["revenue"]))
    res4 = QUERIES["Q4.2"](data)
    n_groups_hit = int(np.sum(np.asarray(res4["profit"]) != 0))
    assert n_groups_hit > 0


def test_synthetic_star_shapes():
    s = generate_star(setting=2, sf=1, k=12, scale=0.1)
    assert s.star.feature_width == 12
    t = s.star.materialize()
    assert t.shape[1] == 12


# -------------------------------------------------------------- runtime ----
def test_heartbeat_failure_detection():
    t = {"now": 0.0}
    mon = HeartbeatMonitor([0, 1, 2], timeout_s=10.0,
                           clock=lambda: t["now"])
    t["now"] = 5.0
    mon.beat(0)
    mon.beat(1)
    t["now"] = 12.0
    assert mon.failed_hosts() == [2]
    assert sorted(mon.alive_hosts()) == [0, 1]


def test_elastic_remesh_sheds_dp_keeps_tp():
    plan = elastic_remesh(512, model_parallel=16, devices_per_pod=256)
    assert plan.shape == (2, 16, 16)
    # Lose 10 devices → one pod no longer complete → single flat mesh.
    plan = elastic_remesh(502, model_parallel=16, devices_per_pod=256)
    assert plan.axes[-1] == "model" and plan.shape[-1] == 16
    assert plan.n_devices <= 502
    # TP must survive.
    with pytest.raises(RuntimeError):
        elastic_remesh(8, model_parallel=16)


def test_straggler_detection_and_recovery_flow(tmp_path):
    cluster = SimulatedCluster(n_hosts=8)
    strag = StragglerMonitor(range(8), threshold=1.5, patience=2)
    cluster.make_slow(5, 3.0)
    flagged = []
    for _ in range(4):
        flagged = strag.record_step(cluster.step_times())
    assert flagged == [5]
    # Failure → heartbeat detect → remesh smaller.
    cluster.fail_host(3)
    cluster.advance(40.0)
    assert 3 in cluster.monitor.failed_hosts()
    plan = elastic_remesh(cluster.alive_devices, model_parallel=4,
                          devices_per_pod=cluster.alive_devices)
    assert plan.n_devices <= cluster.alive_devices
