"""HLO analyzer: trip-count-aware FLOPs/collective accounting vs ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import HloAnalyzer, xla_cost_analysis


def test_scan_flops_multiplied_by_trip_count():
    n_iter, b, d = 7, 32, 64

    def scanned(ws, x):
        def step(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, ws)
        return y

    ws = jax.ShapeDtypeStruct((n_iter, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)
    compiled = jax.jit(scanned).lower(ws, x).compile()
    costs = HloAnalyzer(compiled.as_text()).analyze()
    want = 2.0 * b * d * d * n_iter
    assert costs.flops == pytest.approx(want, rel=0.05)
    # XLA's own cost_analysis undercounts by ~n_iter (the bug we fix);
    # its return shape is version-dependent (list-of-dict vs dict).
    xla_flops = xla_cost_analysis(compiled)["flops"]
    assert xla_flops < want / 2


def test_xla_cost_analysis_normalizes_shapes():
    class ReturnsNone:           # backends where cost_analysis is unavailable
        def cost_analysis(self):
            return None

    class ReturnsList:           # jax ≤ 0.4.x: one dict per partition
        def cost_analysis(self):
            return [{"flops": 2.0}]

    class ReturnsDict:           # newer jax
        def cost_analysis(self):
            return {"flops": 3.0}

    assert xla_cost_analysis(ReturnsNone()) == {}
    assert xla_cost_analysis(ReturnsList()) == {"flops": 2.0}
    assert xla_cost_analysis(ReturnsDict()) == {"flops": 3.0}


def test_nested_scan_flops():
    def nested(ws, x):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    ws = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    compiled = jax.jit(nested).lower(ws, x).compile()
    costs = HloAnalyzer(compiled.as_text()).analyze()
    want = 2.0 * 8 * 16 * 16 * 5 * 3
    assert costs.flops == pytest.approx(want, rel=0.1)


def test_collective_bytes_with_groups(monkeypatch):
    import subprocess, sys, json, textwrap
    # Run in a subprocess with 4 fake devices so this test doesn't disturb
    # the process-wide device count.
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, json, sys
        sys.path.insert(0, "src")
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import HloAnalyzer
        mesh = jax.make_mesh((4,), ("model",))
        def f(w, x):
            return x @ w
        with mesh:
            ws = NamedSharding(mesh, P(None, "model"))
            w = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=ws)
            x = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                                     sharding=NamedSharding(mesh, P(None, None)))
            compiled = jax.jit(f, out_shardings=NamedSharding(
                mesh, P(None, None))).lower(w, x).compile()
        c = HloAnalyzer(compiled.as_text()).analyze()
        print(json.dumps({"coll": c.total_coll_bytes,
                          "n": c.n_collectives,
                          "flops": c.flops}))
    """)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # Output (8,64) f32 must be all-gathered from 4-way shards (or the
    # compiler's equivalent): some collective traffic, correct flops.
    assert res["n"] >= 1
    assert res["coll"] > 0
    assert res["flops"] == pytest.approx(2 * 8 * 64 * 16, rel=0.05)


def test_memory_bytes_reasonable():
    def f(x):
        return jnp.tanh(x) * 2.0

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    costs = HloAnalyzer(compiled.as_text()).analyze()
    nbytes = 1024 * 1024 * 4
    # Read + write ≈ 2 buffers; allow fusion bookkeeping slack.
    assert nbytes <= costs.mem_bytes <= 6 * nbytes
