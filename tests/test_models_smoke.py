"""Per-architecture smoke tests: reduced configs, one forward + train step.

Full configs are exercised only via the AOT dry-run (no allocation); these
reduced configs validate numerics/shapes of every layer family on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get_smoke_config
from repro.models import LM


def _inputs(cfg, rng, batch=2, seq=16):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                         jnp.int32)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        kwargs["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_patches, cfg.d_model)), jnp.float32)
    return tokens, kwargs


@pytest.mark.parametrize("arch", arch_ids())
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    tokens, kwargs = _inputs(cfg, rng)
    logits, aux = jax.jit(model.forward)(params, tokens, **kwargs)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", arch_ids())
def test_train_step_reduces_loss_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(1))
    tokens, kwargs = _inputs(cfg, rng)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, tokens.shape),
                         jnp.int32)

    def loss_fn(p):
        logits, aux = model.forward(p, tokens, **kwargs)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in flat))
    assert float(gnorm) > 0  # gradients flow through every block type

    # One SGD step reduces the loss (sane training signal).
    lr = 0.05
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
    loss2 = jax.jit(loss_fn)(params2)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen2-moe-a2.7b",
                                  "jamba-1.5-large-398b", "xlstm-125m",
                                  "whisper-tiny", "pixtral-12b"])
def test_decode_matches_forward(arch):
    """Prefix decode (token-by-token with caches/states) == full forward."""
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    rng = np.random.default_rng(2)
    params = model.init(jax.random.PRNGKey(2))
    batch, seq = 2, 8
    tokens, kwargs = _inputs(cfg, rng, batch, seq)
    if cfg.family == "vlm":
        # Decode path doesn't stream patches; compare text-only forward.
        kwargs = {}
    full_logits, _ = jax.jit(model.forward)(params, tokens, **kwargs)

    state = model.init_decode_state(params, batch, max_len=seq,
                                    frames=kwargs.get("frames"))
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(seq):
        logits, state = step(params, state, tokens[:, t])
        outs.append(logits)
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), rtol=2e-2,
                               atol=2e-2)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention, naive_attention
    rng = np.random.default_rng(3)
    b, s, h, kv, hd = 2, 256, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    for causal in (True, False):
        fl = np.asarray(flash_attention(q, k, v, causal=causal, q_block=64,
                                        kv_block=32))
        nv = np.asarray(naive_attention(q, k, v, causal=causal))
        np.testing.assert_allclose(fl, nv, rtol=2e-5, atol=2e-5)


def test_flash_attention_custom_vjp_grads_match_naive():
    from repro.models.attention import flash_attention, naive_attention
    rng = np.random.default_rng(7)
    b, s, h, kv, hd = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(h * hd,)), jnp.float32)
    for causal in (True, False):
        def f1(*a):
            return jnp.sum(flash_attention(
                *a, causal=causal, q_block=32, kv_block=16) * w)

        def f2(*a):
            return jnp.sum(naive_attention(*a, causal=causal) * w)
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-4, atol=2e-4)


def test_param_counts_match_assignment():
    """Full-config parameter counts land near the advertised sizes."""
    from repro.configs import get_config
    expect = {
        "smollm-360m": (0.30e9, 0.45e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "minitron-4b": (3.5e9, 5.5e9),  # 256k untied vocab adds ~1.6B
        "gemma-7b": (7.0e9, 10.0e9),
        "pixtral-12b": (11.0e9, 14.0e9),
        "dbrx-132b": (125e9, 140e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
        "xlstm-125m": (0.08e9, 0.20e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),  # total (A2.7b = active)
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
    active = get_config("qwen2-moe-a2.7b").active_params()
    assert 2.0e9 <= active <= 3.5e9
