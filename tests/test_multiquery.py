"""Multi-query optimizer: pooled compilation is bit-exact vs independent
(`compile_query` with and without a shared :class:`ArtifactPool`) across the
whole SSB registry, pool refcounts evict only on last release, a dimension
append refreshes each shared artifact exactly once, ``Session.run_all``
stacks compatible plans bit-exactly, ``_opts_key`` normalizes default
spellings onto one cache entry, and ``explain()`` is unified across
plan/runtime/scheduler.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.core.laq import Catalog
from repro.core.query import (ArtifactPool, ExplainReport, Session,
                              artifact_bytes, compile_query, compile_serving,
                              stack_key)
from repro.data import QUERY_IR, generate_ssb, predictive_query_names, \
    ssb_catalog

ALL_NAMES = sorted(QUERY_IR)


@pytest.fixture(scope="module")
def data():
    return generate_ssb(sf=1, scale=0.0005, seed=5)


@pytest.fixture(scope="module")
def catalog(data):
    return ssb_catalog(data)


def _fresh_session(data):
    ro = ssb_catalog(data)
    return Session(Catalog({n: ro[n] for n in ro}))


def _assert_same_results(a, b, msg=""):
    assert set(a) == set(b), msg
    for k in a:
        assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                           err_msg=f"{msg}:{k}")


# ---------------------------------------------------------------------------
# Tentpole: pooled ≡ independent, bit-exact
# ---------------------------------------------------------------------------
def test_pooled_registry_bit_exact(catalog):
    """Every registry query: pool-shared plan ≡ standalone plan, bit-exact,
    with identical backend decisions (sharing must not change semantics)."""
    pool = ArtifactPool(catalog)
    for name in ALL_NAMES:
        q = QUERY_IR[name]()
        pooled = compile_query(catalog, q, pool=pool)
        solo = compile_query(catalog, q)
        assert (pooled.backend, pooled.join_backend, pooled.agg_backend) == \
            (solo.backend, solo.join_backend, solo.agg_backend), name
        _assert_same_results(pooled.run(), solo.run(), name)
    st = pool.stats()
    assert st["hits"] > 0, "registry shares no artifacts?!"
    assert st["entries"] == st["misses"]


def test_pooled_sharing_reduces_artifacts(catalog):
    """N plans over the same arms hold ONE physical pkindex/join/partial:
    resident derived bytes under the pool are well below independent."""
    pool = ArtifactPool(catalog)
    pooled = [compile_query(catalog, QUERY_IR[n](), pool=pool)
              for n in ALL_NAMES]
    solo = [compile_query(catalog, QUERY_IR[n]()) for n in ALL_NAMES]
    shared, indep = artifact_bytes(pooled), artifact_bytes(solo)
    assert shared < indep / 2, (shared, indep)
    # distinct physical join artifacts: Q2.1/2.2/2.3 share the part arm
    k2 = [p for n, p in zip(ALL_NAMES, pooled) if n.startswith("Q2.")]
    ptrs = {id(fj.ptr) for p in k2 for fj in p.star.joins}
    assert len(ptrs) < sum(len(p.star.joins) for p in k2)


def test_pooled_serving_bit_exact(catalog):
    pool = ArtifactPool(catalog)
    rng = np.random.default_rng(3)
    for name in predictive_query_names():
        q = QUERY_IR[name]()
        pooled = compile_serving(catalog, q, buckets=(4, 16), pool=pool)
        solo = compile_serving(catalog, q, buckets=(4, 16))
        reqs = {a.fk_col: rng.integers(
            0, catalog[a.table].nvalid + 2, size=9).astype(np.int32)
            for a in q.arms}
        assert_array_equal(np.asarray(pooled.serve(reqs)),
                           np.asarray(solo.serve(reqs)), err_msg=name)
    assert pool.stats()["hits"] > 0


def test_pooled_random_subsets_property(catalog):
    """Hypothesis: any subset of the registry, compiled in any order through
    one pool, matches independent compilation bit-exactly."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev)")
    from hypothesis import given, settings, strategies as st

    solo_results = {n: compile_query(catalog, QUERY_IR[n]()).run()
                    for n in ALL_NAMES}

    @settings(max_examples=10, deadline=None)
    @given(st.permutations(ALL_NAMES).map(lambda p: p[:5]))
    def check(names):
        pool = ArtifactPool(catalog)
        for name in names:
            plan = compile_query(catalog, QUERY_IR[name](), pool=pool)
            _assert_same_results(plan.run(), solo_results[name], name)
            plan.close()
        assert pool.stats()["entries"] == 0   # all refs released

    check()


# ---------------------------------------------------------------------------
# Refcounts: eviction only on last release
# ---------------------------------------------------------------------------
def test_refcount_evicts_on_last_release(catalog):
    pool = ArtifactPool(catalog)
    q = QUERY_IR["Q2.1"]()
    a = compile_query(catalog, q, pool=pool)
    b = compile_query(catalog, q, pool=pool)
    keys = set(a._pool_keys())
    assert keys and keys == set(b._pool_keys())
    n0 = pool.stats()["entries"]
    a.close()
    assert pool.stats()["entries"] == n0          # b still holds every key
    assert all(pool.refcount(k) >= 1 for k in keys)
    b.close()
    assert all(pool.refcount(k) == 0 for k in keys)
    assert pool.stats()["entries"] < n0           # last release evicts
    a.close()                                      # idempotent
    assert pool.stats()["evictions"] >= len(keys)


def test_session_evict_drains_pool(data):
    sess = _fresh_session(data)
    for n in ALL_NAMES[:6]:
        sess.compile(QUERY_IR[n]())
    assert sess.pool.stats()["entries"] > 0
    removed = sess.evict()
    assert removed == 6 and sess.num_plans == 0
    assert sess.pool.stats()["entries"] == 0
    assert sess.pool.stats()["bytes"] == 0


def test_session_evict_single_query(data):
    sess = _fresh_session(data)
    q1, q2 = QUERY_IR["Q1.1"](), QUERY_IR["Q1.2"]()
    sess.compile(q1)
    sess.compile(q2)
    assert sess.evict(q1) == 1
    assert sess.num_plans == 1
    assert sess.pool.stats()["entries"] > 0       # q2's artifacts survive
    _ = sess.compile(q2).run()                     # still serviceable


# ---------------------------------------------------------------------------
# Refresh: one update per distinct shared artifact
# ---------------------------------------------------------------------------
def _append_dim_rows(cat, table, frac=0.01):
    t = cat[table]
    n = max(1, int(t.nvalid * frac))
    cols = {}
    for cname in t.columns:
        col = np.asarray(t.col(cname)[:n])
        if cname in t.keys:
            col = np.arange(t.nvalid, t.nvalid + n, dtype=col.dtype)
        cols[cname] = col
    cat.append(table, cols)
    return n


def test_refresh_updates_shared_artifact_once(data):
    """Three plans sharing the 'part' arm + a 1% append: the shared join
    entry is refreshed exactly once, and every plan matches a cold rebuild."""
    sess = _fresh_session(data)
    # first append doubles 'part' capacity, so the measured one below lands
    # inside the padding (delta path, no recompile)
    _append_dim_rows(sess.catalog, "part")
    names = ["Q2.1", "Q2.2", "Q2.3"]
    plans = [sess.compile(QUERY_IR[n]()) for n in names]
    shared = [k for k in plans[0]._pool_keys()
              if k[0] in ("pkindex", "join") and "part" in k]
    assert shared
    before = {k: sess.pool.update_count(k) for k in shared}
    _append_dim_rows(sess.catalog, "part")
    out = sess.refresh()
    assert any("refresh=delta" in line for line in out.values())
    for k in shared:
        assert sess.pool.update_count(k) - before[k] == 1, k
    # refreshed pooled plans ≡ cold standalone compiles on the new catalog
    for n, p in zip(names, plans):
        cold = compile_query(sess.catalog, QUERY_IR[n]())
        _assert_same_results(p.run(), cold.run(), n)


def test_refresh_noop_leaves_update_counts(data):
    sess = _fresh_session(data)
    p = sess.compile(QUERY_IR["Q1.1"]())
    keys = p._pool_keys()
    before = [sess.pool.update_count(k) for k in keys]
    sess.refresh()    # no catalog change
    assert [sess.pool.update_count(k) for k in keys] == before


# ---------------------------------------------------------------------------
# run_all: stacked execution ≡ per-query run()
# ---------------------------------------------------------------------------
def test_run_all_bit_exact(data):
    sess = _fresh_session(data)
    qs = [QUERY_IR[n]() for n in ALL_NAMES]
    batched = sess.run_all(qs)
    for n, q, r in zip(ALL_NAMES, qs, batched):
        _assert_same_results(r, compile_query(sess.catalog, q).run(), n)
    # compatible plans actually stacked (SSB flights share signatures)
    sks = [stack_key(sess.compile(q)) for q in qs]
    real = [k for k in sks if k is not None]
    assert len(set(real)) < len(real)
    # cached stacked runners: second call is exact too
    again = sess.run_all(qs)
    for n, r, r2 in zip(ALL_NAMES, batched, again):
        _assert_same_results(r, r2, f"repeat:{n}")


def test_run_all_accepts_builders_and_survives_refresh(data):
    sess = _fresh_session(data)
    b = (sess.query("lineorder")
         .agg(revenue="sum(lo_revenue)", n="count"))
    [r] = sess.run_all([b])
    solo = b.run()
    _assert_same_results(r, solo, "builder")
    _append_dim_rows(sess.catalog, "supplier")
    qs = [QUERY_IR[n]() for n in ("Q2.1", "Q2.2")]
    for n, r in zip(("Q2.1", "Q2.2"), sess.run_all(qs)):
        cold = compile_query(sess.catalog, QUERY_IR[n]())
        _assert_same_results(r, cold.run(), f"post-append:{n}")


def test_stack_key_excludes_compacted_plans(catalog):
    q = QUERY_IR["Q1.1"]()
    compact = compile_query(catalog, q, select_capacity=4096)
    assert stack_key(compact) is None
    assert stack_key(compile_query(catalog, q)) is not None


# ---------------------------------------------------------------------------
# Session cache-key normalization
# ---------------------------------------------------------------------------
def test_opts_key_defaults_collapse(data):
    sess = _fresh_session(data)
    q = QUERY_IR["Q1.1"]()
    p = sess.compile(q)
    assert sess.compile(q, backend="auto") is p       # explicit default
    assert sess.compile(q, agg_backend="auto") is p
    assert sess.num_plans == 1
    assert sess.compile(q, backend="nonfused") is not p
    assert sess.num_plans == 2


def test_opts_key_serving_bucket_spellings(data):
    sess = _fresh_session(data)
    q = QUERY_IR[predictive_query_names()[0]]()
    r = sess.serving(q, buckets=[64, 8])
    assert sess.serving(q, buckets=(8, 64)) is r      # order-insensitive
    assert sess.serving(q, buckets=(8, 64, 64)) is r  # dupes collapse
    assert sess.num_runtimes == 1
    assert sess.serving(q, buckets=(8, 32)) is not r
    assert sess.num_runtimes == 2


# ---------------------------------------------------------------------------
# Unified explain surface
# ---------------------------------------------------------------------------
def test_explain_unified(data):
    sess = _fresh_session(data)
    q = QUERY_IR["Q2.1"]()
    rep = sess.bind(q).explain()
    assert isinstance(rep, ExplainReport)
    assert rep.kind == "compiled"
    assert rep.shared_artifacts                      # pool-backed plan
    assert str(rep)                                  # legacy one-liner
    d = rep.as_dict()
    assert d["kind"] == "compiled" and isinstance(d["extras"], dict)

    sq = QUERY_IR[predictive_query_names()[0]]()
    srep = sess.serving(sq, buckets=(4,)).explain()
    assert srep.kind == "serving" and srep.shared_artifacts

    sched = sess.scheduler(auto_start=False)
    sched.register(sess.serving(sq, buckets=(4,)), name="p0")
    _append_dim_rows(sess.catalog, sq.arms[0].table)
    sched.refresh()
    crep = sched.explain()
    assert crep.kind == "scheduler"
    assert any("p0:" in line for line in crep.trail)
    sched.close()


def test_pool_bypassed_under_outer_trace(catalog):
    """Compile under an outer jit builds the model from tracers; the pool
    must bypass entirely (content keys need concrete bytes) and the traced
    plan must still run — the ssb_demo jit-wrapped-registry path."""
    import jax
    pool = ArtifactPool(catalog)

    def f():
        q = QUERY_IR["P1.linear.year"]()       # model arrays trace here
        return compile_query(catalog, q, pool=pool).run()

    out = jax.jit(f)()
    ref = compile_query(catalog, QUERY_IR["P1.linear.year"]()).run()
    assert set(out) == set(ref)
    for k in ref:   # whole-pipeline XLA fusion reorders float ops: allclose
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, err_msg=f"traced:{k}")
    assert pool.stats()["misses"] == 0         # never consulted


def test_deprecated_entry_points_warn(data, catalog):
    from repro.data import compiled_plan
    with pytest.warns(DeprecationWarning, match="migration table"):
        compiled_plan("Q1.1", data)
    raw = {n: catalog[n] for n in catalog}
    with pytest.warns(DeprecationWarning, match="plain mapping"):
        compile_query(raw, QUERY_IR["Q1.1"]())
    with pytest.warns(DeprecationWarning, match="plain mapping"):
        compile_serving(raw, QUERY_IR[predictive_query_names()[0]](),
                        buckets=(4,))
