"""Shared test session hooks.

Persistent XLA compilation cache
--------------------------------
Tier-1 wall-clock is dominated by XLA compiles (every jitted program, every
bucket, every backend pair re-lowered per run).  When ``REPRO_JAX_CACHE_DIR``
is set — CI exports it and persists the directory with ``actions/cache``
keyed on (jax version, kernel-source hash) — compiled executables are
reused across runs: the first run on a cold key pays full compile time and
seeds the cache, later runs deserialize.  Unset (the default), behaviour is
exactly as before: no cache, nothing written.

The env-var gate keeps local runs hermetic and makes the CI key explicit;
the version/kernel hash in the *cache key* (not here) guarantees staleness
can only cost a re-compile, never serve a wrong executable (jax also keys
entries by its own fingerprint internally).
"""
import os

import jax


def _init_compilation_cache() -> None:
    cache_dir = os.environ.get("REPRO_JAX_CACHE_DIR")
    if not cache_dir:
        return
    os.makedirs(cache_dir, exist_ok=True)
    try:
        from jax.experimental.compilation_cache import compilation_cache
        compilation_cache.set_cache_dir(cache_dir)
    except (ImportError, AttributeError):
        # Older jax: the config knob predates set_cache_dir.
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    # CPU executables are cacheable but jax skips them by default unless
    # told the backend participates; harmless no-ops where unsupported.
    for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                      ("jax_persistent_cache_min_entry_size_bytes", 0)):
        try:
            jax.config.update(knob, val)
        except AttributeError:
            pass


_init_compilation_cache()
