"""Randomized-workload fuzzing of the compiler against the numpy oracle.

The tier-1 corpus keeps a small fixed-seed batch fast enough for every CI
run; the ``slow`` marker carries the ≥200-case campaign the acceptance bar
asks for (CI runs it in the ``fuzz-smoke`` step / nightly deep-fuzz).  Any
failure message embeds the case seed — replay with
``python scripts/fuzz_repro.py --seed <N>``.
"""

import numpy as np
import pytest

from repro.core.query.workload import (FuzzReport, check_case, generate_case,
                                       np_oracle, run_fuzz)


def test_generator_is_deterministic():
    from repro.core.query import query_key
    a, b = generate_case(123), generate_case(123)
    assert query_key(a.query) == query_key(b.query)
    assert set(a.tables) == set(b.tables)
    for n in a.tables:
        np.testing.assert_array_equal(np.asarray(a.tables[n].matrix),
                                      np.asarray(b.tables[n].matrix))
    # and distinct seeds actually vary the workload
    c = generate_case(124)
    assert (query_key(c.query) != query_key(a.query)
            or set(c.tables) != set(a.tables))


def test_generated_schemas_cover_chains():
    # Across a modest seed range the generator must actually emit
    # multi-hop chains, models, group-bys and predicates — otherwise the
    # fuzz corpus silently stops covering the snowflake subsystem.
    depths, models, grouped, preds = set(), set(), set(), set()
    for seed in range(40):
        q = generate_case(seed).query
        depths.add(max((len(a.links) for a in q.arms), default=0))
        models.add(type(q.model).__name__)
        grouped.add(bool(q.group_keys))
        preds.add(bool(q.fact_preds)
                  or any(a.preds or any(lk.preds for lk in a.links)
                         for a in q.arms))
    assert any(d >= 2 for d in depths)      # depth ≥ 2 chains appear
    assert len(models) >= 2                 # with and without a model
    assert grouped == {True, False}
    assert True in preds


def test_oracle_counts_star_rows():
    case = generate_case(11)
    want = np_oracle(case.tables, case.query)
    assert 0 <= want["rows"] <= int(case.tables[case.query.fact].nvalid)


@pytest.mark.parametrize("seed", [0, 7, 19, 42])
def test_fuzz_case_full_matrix(seed):
    assert check_case(seed, full=True) == []


def test_fuzz_small_corpus():
    rep = run_fuzz(12, seed=2)
    assert isinstance(rep, FuzzReport)
    assert rep.ok, rep.failures
    assert rep.cases == 12 and len(rep.seeds) == 12


@pytest.mark.slow
def test_fuzz_campaign_200_cases():
    rep = run_fuzz(200, seed=0)
    assert rep.ok, rep.failures[:5]
