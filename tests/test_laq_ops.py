"""Unit + property tests for LAQ relational operators vs numpy oracles."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st

from repro.core.laq import (PAD_GROUP, PAD_KEY, DimSpec, Pred, Table,
                            composite_code, groupby_codes, groupby_reduce,
                            groupby_sum_matmul, groupby_sum_segment,
                            join_factored, key_domain, mapping_matrix,
                            materialize_gather, materialize_matmul,
                            matmul_aggregate, mmjoin_bcoo,
                            mmjoin_dense, order_by, positions, project_gather,
                            project_matmul, segment_aggregate, select,
                            selection_vector, star_join)
from helpers_relational import np_equijoin_pairs, np_groupby_sum, np_star_join


def make_table(rng, name, n, ncols, key_names=(), key_max=50, capacity=None):
    cols = {f"c{i}": rng.normal(size=n).astype(np.float32) for i in range(ncols)}
    for k in key_names:
        cols[k] = rng.integers(0, key_max, size=n)
    return Table.from_columns(name, cols, key_cols=key_names, capacity=capacity)


# ---------------------------------------------------------------- projection
def test_projection_matmul_equals_gather():
    rng = np.random.default_rng(0)
    t = make_table(rng, "t", 17, 5)
    a = project_matmul(t, ["c3", "c0", "c4"])
    b = project_gather(t, ["c3", "c0", "c4"])
    np.testing.assert_allclose(np.asarray(a.matrix), np.asarray(b.matrix))
    assert a.columns == ("c3", "c0", "c4")


def test_mapping_matrix_is_binary_column_selector():
    m = mapping_matrix(["a", "b", "c"], ["c", "a"])
    np.testing.assert_array_equal(
        np.asarray(m), np.array([[0, 1], [0, 0], [1, 0]], np.float32))


# ----------------------------------------------------------------- selection
def test_selection_vector_and_compaction():
    rng = np.random.default_rng(1)
    t = make_table(rng, "t", 40, 3, key_names=("k",), key_max=10, capacity=64)
    preds = [Pred("c0", ">", 0.0), Pred("k", "<=", 5)]
    vec = np.asarray(selection_vector(t, preds))
    mat = np.asarray(t.matrix)
    k = np.asarray(t.key("k"))
    expect = ((mat[:, 0] > 0) & (k <= 5)
              & (np.arange(64) < 40)).astype(np.float32)
    np.testing.assert_array_equal(vec, expect)

    out = select(t, preds, capacity=64)
    n = int(out.nvalid)
    assert n == int(expect.sum())
    # Surviving rows preserved, order-stable.
    surv = mat[expect.astype(bool)]
    np.testing.assert_allclose(np.asarray(out.matrix)[:n], surv)
    # Padding rows zeroed / PAD_KEY.
    assert np.all(np.asarray(out.matrix)[n:] == 0)
    assert np.all(np.asarray(out.key("k"))[n:] == PAD_KEY)


def test_selection_between_and_in():
    rng = np.random.default_rng(2)
    t = make_table(rng, "t", 30, 1, key_names=("k",), key_max=20)
    m1 = np.asarray(Pred("k", "between", (5, 10)).mask(t))
    k = np.asarray(t.key("k"))
    np.testing.assert_array_equal(m1, (k >= 5) & (k <= 10))
    m2 = np.asarray(Pred("k", "in", [3, 7, 19]).mask(t))
    np.testing.assert_array_equal(m2, np.isin(k, [3, 7, 19]))


# -------------------------------------------------------------------- domain
def test_key_domain_sorted_union_with_padding():
    a = jnp.asarray(np.array([5, 1, 9, PAD_KEY], np.int32))
    b = jnp.asarray(np.array([9, 2], np.int32))
    dom = np.asarray(key_domain([a, b], size=8))
    assert list(dom[:4]) == [1, 2, 5, 9]
    assert np.all(dom[4:] == PAD_KEY)


def test_positions_miss_and_padding_out_of_range():
    dom = jnp.asarray(np.array([2, 4, 6, PAD_KEY], np.int32))
    keys = jnp.asarray(np.array([4, 3, PAD_KEY, 6], np.int32))
    pos = np.asarray(positions(dom, keys))
    assert pos[0] == 1 and pos[3] == 2
    assert pos[1] == 4 and pos[2] == 4  # out-of-range ⇒ zero one-hot row


# ------------------------------------------------------------------- MM-join
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 24), st.integers(1, 24),
       st.integers(2, 12))
def test_mmjoin_dense_matches_oracle(seed, nr, ns, key_max):
    rng = np.random.default_rng(seed)
    kr = rng.integers(0, key_max, size=nr).astype(np.int32)
    ks = rng.integers(0, key_max, size=ns).astype(np.int32)
    I = np.asarray(mmjoin_dense(jnp.asarray(kr), jnp.asarray(ks),
                                domain_size=2 * key_max))
    pairs = np_equijoin_pairs(kr, ks)
    got = {(i, j) for i, j in zip(*np.nonzero(I > 0.5))}
    assert got == pairs
    assert set(np.unique(I)) <= {0.0, 1.0}


def test_mmjoin_bcoo_matches_dense():
    rng = np.random.default_rng(7)
    kr = rng.integers(0, 15, size=20).astype(np.int32)
    ks = rng.integers(0, 15, size=10).astype(np.int32)
    d = np.asarray(mmjoin_dense(jnp.asarray(kr), jnp.asarray(ks), 32))
    b = np.asarray(mmjoin_bcoo(jnp.asarray(kr), jnp.asarray(ks), 32))
    np.testing.assert_allclose(d, b)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 40), st.integers(1, 20))
def test_join_factored_pkfk_matches_oracle(seed, n_fact, n_dim):
    rng = np.random.default_rng(seed)
    pk = rng.permutation(n_dim * 3)[:n_dim].astype(np.int32)  # unique keys
    fk = rng.choice(np.concatenate([pk, np.arange(n_dim * 3, n_dim * 3 + 5)]),
                    size=n_fact).astype(np.int32)
    fj = join_factored(jnp.asarray(fk), jnp.asarray(pk))
    found = np.asarray(fj.found)
    ptr = np.asarray(fj.ptr)
    for i in range(n_fact):
        matches = np.nonzero(pk == fk[i])[0]
        assert found[i] == (len(matches) == 1)
        if found[i]:
            assert ptr[i] == matches[0]


def test_factored_dense_equals_mmjoin_dense():
    rng = np.random.default_rng(3)
    pk = rng.permutation(30)[:12].astype(np.int32)
    fk = rng.choice(np.concatenate([pk, [97, 98]]), size=25).astype(np.int32)
    fj = join_factored(jnp.asarray(fk), jnp.asarray(pk))
    dense_factored = np.asarray(fj.dense(12))
    dense_mm = np.asarray(mmjoin_dense(jnp.asarray(fk), jnp.asarray(pk), 64))
    np.testing.assert_allclose(dense_factored, dense_mm)


def test_factored_apply_is_I_times_matrix():
    rng = np.random.default_rng(4)
    pk = np.arange(10, dtype=np.int32)
    fk = rng.integers(0, 14, size=20).astype(np.int32)
    x = rng.normal(size=(10, 3)).astype(np.float32)
    fj = join_factored(jnp.asarray(fk), jnp.asarray(pk))
    got = np.asarray(fj.apply(jnp.asarray(x)))
    want = np.asarray(fj.dense(10)) @ x
    np.testing.assert_allclose(got, want, rtol=1e-6)


# ----------------------------------------------------------- materialization
@pytest.mark.slow
def test_materialization_matmul_equals_gather():
    rng = np.random.default_rng(5)
    r = make_table(rng, "r", 15, 2, key_names=("k",), key_max=8)
    s = make_table(rng, "s", 12, 3, key_names=("k",), key_max=8)
    I = mmjoin_dense(r.key("k"), s.key("k"), 16)
    cap = 15 * 12
    a = materialize_matmul(I, r, s, cap)
    b = materialize_gather(I, r, s, cap)
    assert int(a.nvalid) == int(b.nvalid)
    n = int(a.nvalid)
    A = np.asarray(a.matrix)[:n]
    B = np.asarray(b.matrix)[:n]
    # Same multiset of rows (nonzero order may differ only deterministically).
    np.testing.assert_allclose(A, B, rtol=1e-6)
    assert int(a.nvalid) == len(np_equijoin_pairs(np.asarray(r.key("k"))[:15],
                                                  np.asarray(s.key("k"))[:12]))


# -------------------------------------------------------------------- groupby
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 2))
def test_groupby_sum_matmul_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    nr, ns, key_max = 20, 8, 12
    kr = rng.integers(0, key_max, size=nr).astype(np.int32)
    vr = rng.integers(-5, 6, size=nr).astype(np.float32)
    ks = rng.permutation(key_max)[:ns].astype(np.int32)  # unique S keys
    gs = rng.integers(0, 4, size=ns).astype(np.int32)
    grp, sums = groupby_sum_matmul(jnp.asarray(kr), jnp.asarray(vr),
                                   jnp.asarray(ks), jnp.asarray(gs),
                                   domain_size=2 * key_max, num_groups=6)
    want = np_groupby_sum(kr, vr, ks, gs)
    got = {int(g): float(s) for g, s in zip(np.asarray(grp), np.asarray(sums))
           if int(g) != PAD_KEY}
    # Drop zero-valued groups from comparison where absent in oracle.
    for g, s in want.items():
        assert got.get(g, 0.0) == pytest.approx(s, rel=1e-5, abs=1e-4)
    for g, s in got.items():
        if g not in want:
            assert s == pytest.approx(0.0, abs=1e-4)


def test_groupby_reduce_ops():
    codes = jnp.asarray(np.array([3, 1, 3, 1, 2, 2**31 - 1], np.int32))
    vals = jnp.asarray(np.array([1., 2., 3., 4., 5., 100.], np.float32))
    uniq, (s, c, mn, mx, mean) = groupby_reduce(
        codes, [vals] * 5, num_groups=4,
        ops=("sum", "count", "min", "max", "mean"))
    u = np.asarray(uniq)
    assert list(u[:3]) == [1, 2, 3]
    np.testing.assert_allclose(np.asarray(s)[:3], [6., 5., 4.])
    np.testing.assert_allclose(np.asarray(c)[:3], [2., 1., 2.])
    np.testing.assert_allclose(np.asarray(mn)[:3], [2., 5., 1.])
    np.testing.assert_allclose(np.asarray(mx)[:3], [4., 5., 3.])
    np.testing.assert_allclose(np.asarray(mean)[:3], [3., 5., 2.])


def test_composite_code_roundtrip():
    from repro.core.laq import decode_composite
    a = jnp.asarray(np.array([1, 2, 0], np.int32))
    b = jnp.asarray(np.array([4, 0, 9], np.int32))
    valid = jnp.asarray(np.array([True, True, True]))
    code = composite_code([a, b], [3, 10], valid)
    da, db = decode_composite(code, [3, 10])
    np.testing.assert_array_equal(np.asarray(da), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(db), np.asarray(b))


# ----------------------------------------------------------------------- sort
def test_order_by_lexicographic_padding_last():
    rng = np.random.default_rng(6)
    t = make_table(rng, "t", 10, 2, capacity=16)
    out = order_by(t, ["c0", "c1"], descending=[False, True])
    m = np.asarray(out.matrix)[:10]
    keys = list(zip(m[:, 0], -m[:, 1]))
    assert keys == sorted(keys)
    assert np.all(np.asarray(out.matrix)[10:] == 0)


# ------------------------------------------------------------------ star join
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 2))
def test_star_join_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n_fact = 30
    dims_np, fact_cols, dim_specs = [], {}, []
    for d, (n_dim, ncols) in enumerate([(8, 2), (6, 3), (5, 2)]):
        pk = rng.permutation(n_dim * 2)[:n_dim].astype(np.int32)
        fm = rng.normal(size=(n_dim, ncols)).astype(np.float32)
        cols = {f"f{j}": fm[:, j] for j in range(ncols)}
        cols["pk"] = pk
        dim = Table.from_columns(f"dim{d}", cols, key_cols=("pk",))
        fk = rng.choice(np.concatenate([pk, [99]]), size=n_fact)
        fact_cols[f"fk{d}"] = fk
        dims_np.append((pk, fm, fk))
        dim_specs.append(DimSpec(dim, f"fk{d}", "pk",
                                 tuple(f"f{j}" for j in range(ncols))))
    fact_cols["val"] = rng.normal(size=n_fact).astype(np.float32)
    fact = Table.from_columns("fact", fact_cols,
                              key_cols=tuple(f"fk{d}" for d in range(3)))
    sj = star_join(fact, dim_specs)
    t_gather = np.asarray(sj.materialize())
    t_matmul = np.asarray(sj.materialize_matmul())
    np.testing.assert_allclose(t_gather, t_matmul, rtol=1e-5, atol=1e-5)

    rows, feats = np_star_join([d[2] for d in dims_np],
                               [(d[0], d[1]) for d in dims_np])
    valid = np.asarray(sj.row_valid)
    np.testing.assert_array_equal(np.nonzero(valid)[0], rows)
    if len(rows):
        np.testing.assert_allclose(t_gather[rows], feats, rtol=1e-5)
    # Invalid rows are zero.
    assert np.all(t_gather[~valid] == 0)


# --------------------------------------- factored vs dense join equivalence
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 30), st.integers(1, 12),
       st.sampled_from(["mix", "all_miss", "dup_fk"]))
def test_join_factored_equals_mmjoin_dense_and_bcoo(seed, n_fact, n_dim,
                                                    regime):
    """I = onehot(ptr) (factored) == MAT_R MAT_Sᵀ (dense/BCOO) under
    duplicate FKs, all-miss FKs, and PAD_KEY padding on both sides."""
    rng = np.random.default_rng(seed)
    pk = rng.permutation(n_dim * 3)[:n_dim].astype(np.int32)
    if regime == "all_miss":
        fk = rng.integers(n_dim * 3, n_dim * 3 + 7,
                          size=n_fact).astype(np.int32)
    elif regime == "dup_fk":
        fk = np.full(n_fact, pk[rng.integers(0, n_dim)], np.int32)
    else:
        pool = np.concatenate([pk, pk, [n_dim * 3 + 1]])  # dups + a miss
        fk = rng.choice(pool, size=n_fact).astype(np.int32)
    # Table padding on both sides.
    fk_p = jnp.asarray(np.concatenate([fk, [PAD_KEY, PAD_KEY]]).astype(
        np.int32))
    pk_p = jnp.asarray(np.concatenate([pk, [PAD_KEY]]).astype(np.int32))

    fj = join_factored(fk_p, pk_p)
    dense_factored = np.asarray(fj.dense(pk_p.shape[0]))
    dom = n_dim * 3 + 10
    dense_mm = np.asarray(mmjoin_dense(fk_p, pk_p, dom))
    np.testing.assert_array_equal(dense_factored, dense_mm)
    dense_bcoo = np.asarray(mmjoin_bcoo(fk_p, pk_p, dom))
    np.testing.assert_array_equal(dense_mm, dense_bcoo)
    # PAD rows never match, in either representation.
    assert np.all(dense_factored[-2:] == 0)
    assert np.all(dense_factored[:, -1] == 0)
    if regime == "all_miss":
        assert not np.asarray(fj.found).any()


# --------------------------------------- groupby segment ≡ matmul (Fig. 4)
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 30), st.integers(1, 10),
       st.booleans())
def test_groupby_sum_segment_equals_matmul(seed, nr, ns, pad_rows):
    """segment_sum group-by == Fig. 4 one-hot matmul group-by, including
    PAD_KEY rows on both relations and missing-key fact rows."""
    rng = np.random.default_rng(seed)
    key_max = 16
    kr = rng.integers(0, key_max, size=nr).astype(np.int32)
    vr = rng.integers(-5, 6, size=nr).astype(np.float32)
    ks = rng.permutation(key_max)[:ns].astype(np.int32)  # unique S keys
    gs = rng.integers(0, 4, size=ns).astype(np.int32)
    if pad_rows:
        kr = np.concatenate([kr, [PAD_KEY]]).astype(np.int32)
        vr = np.concatenate([vr, [123.0]]).astype(np.float32)
        ks = np.concatenate([ks, [PAD_KEY]]).astype(np.int32)
        gs = np.concatenate([gs, [PAD_GROUP]]).astype(np.int32)
    args = (jnp.asarray(kr), jnp.asarray(vr), jnp.asarray(ks),
            jnp.asarray(gs))
    grp_m, sums_m = groupby_sum_matmul(*args, domain_size=2 * key_max,
                                       num_groups=6)
    grp_s, sums_s = groupby_sum_segment(*args, domain_size=2 * key_max,
                                        num_groups=6)
    np.testing.assert_array_equal(np.asarray(grp_m), np.asarray(grp_s))
    np.testing.assert_allclose(np.asarray(sums_m), np.asarray(sums_s),
                               rtol=1e-6, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 2), st.integers(1, 40), st.integers(1, 5))
def test_code_aggregate_segment_equals_matmul(seed, n, width):
    """The compiler's code-level backends agree on (n,) and (n, l) values,
    with PAD_GROUP rows dropped by both."""
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 9, size=n).astype(np.int32)
    codes[rng.random(n) < 0.2] = PAD_GROUP
    uniq, gid = groupby_codes(jnp.asarray(codes), num_groups=12)
    vals1 = jnp.asarray(rng.integers(-4, 5, size=n).astype(np.float32))
    vals2 = jnp.asarray(rng.integers(-4, 5, size=(n, width)).astype(
        np.float32))
    for vals in (vals1, vals2):
        a = np.asarray(segment_aggregate(gid, vals, 12))
        b = np.asarray(matmul_aggregate(gid, vals, 12))
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-5)
    # PAD_GROUP rows contribute to no group.
    live = codes != PAD_GROUP
    np.testing.assert_allclose(
        np.asarray(segment_aggregate(gid, vals1, 12)).sum(),
        np.asarray(vals1)[live].sum(), rtol=1e-6, atol=1e-4)
