"""Session query-builder API: builder ≡ hand-built IR (plan-cache hit, no
re-trace), multi-aggregate lowering vs the numpy oracle on both aggregation
backends, registry bit-exactness through the Session, ``num_groups="auto"``,
backend-keyed planner thresholds, and ``eval_value`` error reporting."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import LinearOperator, random_tree
from repro.core.laq import PAD_GROUP, Pred
from repro.core.query import (COUNT_STAR, PLANNER_THRESHOLDS, PREDICTION,
                              Aggregate, ArmSpec, GroupKey, PredictiveQuery,
                              Session, compile_query, compile_serving,
                              eval_value, plan_aggregation, plan_query,
                              planner_threshold, query, query_key,
                              requests_from_rows)
from repro.data import QUERY_IR, generate_ssb, ssb_catalog, ssb_session
from helpers_relational import np_predictive_query

ALL_NAMES = sorted(QUERY_IR)


@pytest.fixture(scope="module")
def data():
    return generate_ssb(sf=1, scale=0.0005, seed=5)


@pytest.fixture(scope="module")
def catalog(data):
    return ssb_catalog(data)


def _linear(k, l, seed=0):
    rng = np.random.default_rng(seed)
    return LinearOperator(jnp.asarray(
        rng.normal(size=(k, l)).astype(np.float32) / np.sqrt(k)))


# ------------------------------------------------- builder ≡ hand-built IR
def test_builder_lowers_to_handbuilt_ir(catalog):
    model = _linear(3, 2)
    built = (query("lineorder")
             .join("date", on=("lo_orderdate", "datekey"),
                   features=["d_month", "d_weeknuminyear"],
                   where=[("d_year", "==", 1993)])
             .join("supplier", on=("lo_suppkey", "suppkey"),
                   features=["s_city"])
             .where(("lo_discount", "between", (1, 3)))
             .predict(model)
             .group_by(("date", "d_year", 8, 1992), num_groups=8)
             .agg(revenue="sum(lo_revenue)", preds=("mean", PREDICTION),
                  n="count")
             .build())
    hand = PredictiveQuery(
        fact="lineorder",
        arms=(ArmSpec("date", "lo_orderdate", "datekey",
                      ("d_month", "d_weeknuminyear"),
                      (Pred("d_year", "==", 1993),)),
              ArmSpec("supplier", "lo_suppkey", "suppkey", ("s_city",))),
        fact_preds=(Pred("lo_discount", "between", (1, 3)),),
        model=model,
        group_keys=(GroupKey("date", "d_year", 8, 1992),),
        aggregates=(Aggregate("lo_revenue", "sum", "revenue"),
                    Aggregate(PREDICTION, "mean", "preds"),
                    Aggregate(COUNT_STAR, "count", "n")),
        num_groups=8)
    for f in dataclasses.fields(PredictiveQuery):
        assert getattr(built, f.name) == getattr(hand, f.name), f.name
    assert query_key(built) == query_key(hand)


def test_registry_builders_hit_plan_cache(data):
    """Rebuilding a registry query (fresh model objects each call) must
    produce a hash-equal IR and hit the session's plan cache — the
    structural key, not object identity, owns reuse."""
    sess = ssb_session(data)
    for name in ("Q3.2", "P1.linear.year", "P4.tree.select.region"):
        q1, q2 = QUERY_IR[name](), QUERY_IR[name]()
        assert q1 is not q2
        assert query_key(q1) == query_key(q2), name
        assert sess.compile(q1) is sess.compile(q2), name


def test_property_builder_ir_hash_equal():
    """Property: any builder-constructed query is hash-equal to its
    hand-built ``PredictiveQuery`` (same plan-cache key, so no re-trace)."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev)")
    from hypothesis import given, settings, strategies as st

    arms_pool = [
        ("part", "lo_partkey", "partkey", ("p_size", "p_category"),
         (Pred("p_category", "<", 10),)),
        ("supplier", "lo_suppkey", "suppkey", ("s_city",), ()),
        ("date", "lo_orderdate", "datekey", ("d_month",),
         (Pred("d_year", "between", (1993, 1995)),)),
    ]
    fact_pool = [Pred("lo_discount", "between", (1, 3)),
                 Pred("lo_quantity", "<", 25)]
    gk_pool = [GroupKey("date", "d_year", 8, 1992),
               GroupKey("part", "p_brand1", 1000)]
    agg_pool = [("revenue", ("sum", ("mul", "lo_extendedprice",
                                     "lo_discount"))),
                ("q_mean", "mean(lo_quantity)"),
                ("n", "count"),
                ("q_min", "min(lo_quantity)"),
                ("preds", ("max", PREDICTION))]
    model = _linear(4, 2)

    @settings(max_examples=60, deadline=None)
    @given(n_arms=st.integers(1, 3),
           fact_preds=st.booleans(),
           with_model=st.booleans(),
           n_gks=st.integers(0, 2),
           aggs=st.sets(st.integers(0, 4), min_size=1, max_size=4),
           num_groups=st.sampled_from([64, 8192, "auto"]))
    def check(n_arms, fact_preds, with_model, n_gks, aggs, num_groups):
        picked = arms_pool[:n_arms]
        agg_items = [agg_pool[i] for i in sorted(aggs)
                     if with_model or agg_pool[i][0] != "preds"]
        if not agg_items:
            agg_items = [agg_pool[2]]

        b = query("lineorder")
        for table, fk, pk, feats, preds in picked:
            b = b.join(table, on=(fk, pk), features=feats, where=preds)
        if fact_preds:
            b = b.where(*fact_pool)
        if with_model:
            b = b.predict(model)
        if n_gks:
            b = b.group_by(*gk_pool[:n_gks], num_groups=num_groups)
        b = b.agg(**dict(agg_items))

        hand = PredictiveQuery(
            fact="lineorder",
            arms=tuple(ArmSpec(t, fk, pk, f, p)
                       for t, fk, pk, f, p in picked),
            fact_preds=tuple(fact_pool) if fact_preds else (),
            model=model if with_model else None,
            group_keys=tuple(gk_pool[:n_gks]),
            aggregates=tuple(
                {"revenue": Aggregate(("mul", "lo_extendedprice",
                                       "lo_discount"), "sum", "revenue"),
                 "q_mean": Aggregate("lo_quantity", "mean", "q_mean"),
                 "n": Aggregate(COUNT_STAR, "count", "n"),
                 "q_min": Aggregate("lo_quantity", "min", "q_min"),
                 "preds": Aggregate(PREDICTION, "max", "preds"),
                 }[name] for name, _ in agg_items),
            num_groups=num_groups if n_gks else 8192)
        built = b.build()
        for f in dataclasses.fields(PredictiveQuery):
            assert getattr(built, f.name) == getattr(hand, f.name), f.name
        assert query_key(built) == query_key(hand)

    check()


# ------------------------------------- registry bit-exact through Session
@pytest.mark.parametrize("name", ALL_NAMES)
def test_registry_query_session_bit_exact(name, data, catalog):
    """All 13 SSB + 4 P* queries through the Session produce bit-exact
    results vs the pre-redesign direct ``compile_query`` path."""
    sess = ssb_session(data)
    got = sess.bind(QUERY_IR[name]()).run()
    want = compile_query(catalog, QUERY_IR[name]()).run()
    assert sorted(got) == sorted(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)


def test_session_rows_and_serve_match_old_entry_points(data, catalog):
    q = QUERY_IR["P1.linear.year"]()
    sess = ssb_session(data)
    ids = jnp.asarray([0, 1, 5, 17, 100, 2999], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(sess.bind(q).rows(ids)),
        np.asarray(compile_query(catalog, q).predict_rows(ids)))
    runtime = sess.bind(q).serve(buckets=(8, 64))
    old = compile_serving(catalog, q, buckets=(8, 64))
    reqs = requests_from_rows(catalog["lineorder"], q, np.arange(6))
    np.testing.assert_array_equal(np.asarray(runtime.serve(reqs)),
                                  np.asarray(old.serve(reqs)))
    assert runtime is sess.bind(QUERY_IR["P1.linear.year"]()).serve(
        buckets=(8, 64)), "serving runtimes must be structurally cached"


def test_mesh_override_does_not_collide_in_plan_cache(catalog):
    """A per-call mesh override must compile a sibling plan, not return the
    cached meshless one (and vice versa)."""
    from repro.launch.mesh import make_serving_mesh
    sess = Session(catalog)
    q = QUERY_IR["P1.linear.year"]()
    meshless = sess.compile(q)
    sharded = sess.compile(q, mesh=make_serving_mesh((1, 1)))
    assert meshless is not sharded
    assert meshless.plan.partition_specs is None
    assert sharded.plan.partition_specs is not None
    assert sess.compile(q) is meshless


# --------------------------------------------- multi-aggregate vs oracle
def _assert_matches_oracle(compiled, q, catalog):
    res = compiled.run()
    want = np_predictive_query(q, catalog)
    assert int(res["rows"]) == want["rows"]
    if want["groups"] is None:
        for a in q.aggregates:
            got = np.atleast_1d(np.asarray(res[a.name]))
            tol = 1e-6 * max(want["abs_scale"][a.name], 1.0)
            np.testing.assert_allclose(
                got, np.atleast_1d(want["scalars"][a.name]),
                rtol=1e-4, atol=tol, err_msg=a.name)
        return
    groups = np.asarray(res["groups"])
    live = groups != PAD_GROUP
    for a in q.aggregates:
        vals = np.asarray(res[a.name])
        v2 = vals if vals.ndim > 1 else vals[:, None]
        got = {int(g): v2[i] for i, g in enumerate(groups) if live[i]}
        want_g = {c: v[a.name] for c, v in want["groups"].items()}
        assert set(got) == set(want_g), a.name
        tol = 1e-6 * max(want["abs_scale"][a.name], 1.0)
        for c, v in want_g.items():
            np.testing.assert_allclose(got[c], v, rtol=1e-4, atol=tol,
                                       err_msg=f"{a.name} group {c}")


_MULTI_AGGS = dict(
    revenue=("sum", ("mul", "lo_extendedprice", "lo_discount")),
    rev_mean=("mean", ("mul", "lo_extendedprice", "lo_discount")),
    n="count",
    q_min="min(lo_quantity)",
    q_max="max(lo_quantity)",
)


@pytest.mark.parametrize("agg_backend", ["segment", "matmul"])
@pytest.mark.parametrize("grouped", [True, False], ids=["grouped", "scalar"])
def test_relational_multi_aggregate_matches_oracle(agg_backend, grouped,
                                                   data, catalog):
    """count/mean/min/max over a fact expression, both agg backends, with
    and without group keys — vs the brute-force numpy oracle."""
    sess = ssb_session(data)
    b = (sess.query("lineorder")
         .join("date", on=("lo_orderdate", "datekey"))
         .where(("lo_discount", "between", (1, 5)))
         .agg(**_MULTI_AGGS))
    if grouped:
        b = b.group_by(("date", "d_year", 8, 1992), num_groups=8)
    q = b.build()
    compiled = b.compile(agg_backend=agg_backend)
    assert compiled.agg_backend == agg_backend or not grouped
    _assert_matches_oracle(compiled, q, catalog)


@pytest.mark.parametrize("agg_backend", ["segment", "matmul"])
@pytest.mark.parametrize("backend", ["fused", "nonfused"])
@pytest.mark.parametrize("head", ["linear", "tree"])
def test_prediction_multi_aggregate_matches_oracle(agg_backend, backend,
                                                   head, data, catalog):
    """≥2 named aggregates (mean + count + sum/min/max of PREDICTION) in one
    compiled program, across fused/nonfused × segment/matmul — vs the
    numpy oracle."""
    model = (_linear(3, 4, seed=7) if head == "linear"
             else random_tree(np.random.default_rng(7), 3, depth=2))
    sess = ssb_session(data)
    b = (sess.query("lineorder")
         .join("part", on=("lo_partkey", "partkey"),
               features=["p_size", "p_category"])
         .join("date", on=("lo_orderdate", "datekey"),
               features=["d_month"],
               where=[("d_year", "between", (1993, 1996))])
         .predict(model)
         .group_by(("date", "d_year", 8, 1992), num_groups=8)
         .agg(psum=("sum", PREDICTION), pmean=("mean", PREDICTION),
              n="count", pmax=("max", PREDICTION)))
    q = b.build()
    compiled = b.compile(backend=backend, agg_backend=agg_backend)
    assert compiled.backend == backend
    res = compiled.run()
    assert {"psum", "pmean", "n", "pmax"} <= set(res)
    _assert_matches_oracle(compiled, q, catalog)
    # mean must be exactly the fused sum/count of the same program.
    n = np.asarray(res["n"])[:, None]
    np.testing.assert_allclose(np.asarray(res["pmean"]),
                               np.asarray(res["psum"]) / np.maximum(n, 1.0),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------- num_groups="auto"
def test_num_groups_auto_sizes_to_measured_domain(data, catalog):
    sess = ssb_session(data)
    base = QUERY_IR["P1.linear.year"]()
    auto = sess.compile(dataclasses.replace(base, num_groups="auto"))
    assert isinstance(auto.query.num_groups, int)
    live = int(np.sum(np.asarray(auto.run()["groups"]) != PAD_GROUP))
    assert auto.query.num_groups == live
    ref = sess.compile(base).run()
    got = auto.run()
    for k in ("prediction", "groups"):
        np.testing.assert_array_equal(
            np.asarray(got[k]),
            np.asarray(ref[k])[:auto.query.num_groups], err_msg=k)


def test_num_groups_auto_raises_under_trace(data, catalog):
    import jax
    q = dataclasses.replace(QUERY_IR["Q2.1"](), num_groups="auto")
    with pytest.raises(ValueError, match="auto"):
        jax.jit(lambda: compile_query(catalog, q).run()["revenue"])()


# --------------------------------------------- eval_value error reporting
def test_eval_value_unknown_column_names_expression(catalog):
    fact = catalog["lineorder"]
    with pytest.raises(ValueError, match="no_such_col"):
        eval_value(fact, "no_such_col")
    with pytest.raises(ValueError, match="lineorder"):
        eval_value(fact, ("mul", "lo_revenue", "no_such_col"))
    with pytest.raises(ValueError, match="my query"):
        eval_value(fact, "no_such_col", query="my query")


@pytest.mark.parametrize("expr, match", [
    (("pow", "lo_revenue", "lo_discount"), "unknown op"),
    (("mul", "lo_revenue"), "takes 2 arguments"),
    (("col",), "exactly one column name"),
    ((), "malformed"),
    (123, "malformed"),
])
def test_eval_value_malformed_expression(catalog, expr, match):
    with pytest.raises(ValueError, match=match):
        eval_value(catalog["lineorder"], expr)


def test_compile_surfaces_bad_aggregate_column(data, catalog):
    sess = ssb_session(data)
    b = (sess.query("lineorder")
         .join("date", on=("lo_orderdate", "datekey"))
         .agg(bad="sum(no_such_col)"))
    with pytest.raises(ValueError, match="no_such_col"):
        b.run()


def test_compile_rejects_bad_aggregates(catalog):
    base = query("lineorder").join("date", on=("lo_orderdate", "datekey"))
    with pytest.raises(ValueError, match="not one of"):
        compile_query(catalog, dataclasses.replace(
            base.agg(x="lo_revenue").build(),
            aggregates=(Aggregate("lo_revenue", "median", "x"),)))
    with pytest.raises(ValueError, match="distinct"):
        compile_query(catalog, dataclasses.replace(
            base.build(),
            aggregates=(Aggregate("lo_revenue", "sum", "x"),
                        Aggregate("lo_quantity", "sum", "x"))))
    with pytest.raises(ValueError, match="reserved"):
        compile_query(catalog, dataclasses.replace(
            base.build(),
            aggregates=(Aggregate("lo_revenue", "sum", "rows"),)))


# ------------------------------------------ builder validation ergonomics
def test_builder_validates_catalog_names(catalog):
    sess = Session(catalog)
    with pytest.raises(KeyError, match="no_such_table"):
        sess.query("no_such_table")
    b = sess.query("lineorder")
    with pytest.raises(KeyError, match="no_such_dim"):
        b.join("no_such_dim", on=("lo_orderdate", "datekey"))
    with pytest.raises(ValueError, match="not a key column"):
        b.join("date", on=("lo_orderdate", "not_a_key"))
    with pytest.raises(ValueError, match="not a key column"):
        b.join("date", on=("lo_revenue", "datekey"))  # float, not a fact key
    with pytest.raises(ValueError, match="feature columns"):
        b.join("date", on=("lo_orderdate", "datekey"),
               features=["nope"])
    with pytest.raises(ValueError, match="detached"):
        query("lineorder").join(
            "date", on=("lo_orderdate", "datekey")).run()


def test_agg_spec_grammar():
    b = query("lineorder").join("date", on=("lo_orderdate", "datekey"))
    q = b.agg(a="lo_revenue", b="mean(lo_quantity)", c="count",
              d=("sum", ("mul", "x", "y")), e=("sub", "x", "y"),
              f=Aggregate("lo_revenue", "max", "ignored")).build()
    assert q.aggregates == (
        Aggregate("lo_revenue", "sum", "a"),
        Aggregate("lo_quantity", "mean", "b"),
        Aggregate(COUNT_STAR, "count", "c"),
        Aggregate(("mul", "x", "y"), "sum", "d"),
        Aggregate(("sub", "x", "y"), "sum", "e"),
        Aggregate("lo_revenue", "max", "f"))
    with pytest.raises(ValueError, match="unparseable"):
        b.agg(x=("median", "lo_revenue"))
    with pytest.raises(ValueError, match="unparseable"):
        b.agg(x=3.14)


# ------------------------------------- backend-keyed planner thresholds
def test_planner_threshold_backend_keyed():
    assert (planner_threshold("MXU_SEGMENT_ADVANTAGE", "cpu")
            == PLANNER_THRESHOLDS["default"]["MXU_SEGMENT_ADVANTAGE"])
    assert (planner_threshold("DENSE_JOIN_ELEMS", "weird_accel")
            == PLANNER_THRESHOLDS["default"]["DENSE_JOIN_ELEMS"])
    with pytest.raises(KeyError, match="unknown planner threshold"):
        planner_threshold("NOT_A_THRESHOLD")
    PLANNER_THRESHOLDS["faketpu"] = {"DENSE_JOIN_ELEMS": 1}
    try:
        # The calibration row flips the decision with zero refactoring:
        # tiny inputs pick the dense matmul join on cpu, gather on faketpu.
        assert plan_query(None, 64, [16, 16]).join_backend == "matmul"
        assert plan_query(None, 64, [16, 16],
                          platform="faketpu").join_backend == "gather"
    finally:
        del PLANNER_THRESHOLDS["faketpu"]


def test_plan_aggregation_costs_combined_set():
    # sum-only: unchanged crossover (compiler tests pin the boundary).
    assert plan_aggregation(100_000, 4, 4).backend == "matmul"
    assert plan_aggregation(100_000, 8192, 1).backend == "segment"
    # min/max-only sets have no matmul lowering to win with.
    assert plan_aggregation(100_000, 4, 4,
                            ops=("min", "max")).backend == "segment"
    # A count rides along without flipping a small-G matmul win …
    assert plan_aggregation(100_000, 4, 4,
                            ops=("sum", "mean", "count")).backend == "matmul"
    # … and the combined set costs more than the single sum.
    single = plan_aggregation(100_000, 4, 4)
    combo = plan_aggregation(100_000, 4, 4, ops=("sum", "mean", "count",
                                                 "min"))
    assert combo.matmul_flops > single.matmul_flops
    assert combo.segment_flops > single.segment_flops
