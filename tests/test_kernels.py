"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:   # degrade the property test to a skip, not an error
    HAS_HYPOTHESIS = False

from repro.kernels import (fused_star_gather, fused_star_gather_ref,
                           onehot_matmul, onehot_matmul_ref, tree_predict,
                           tree_predict_ref)
from repro.core.fusion import random_tree


# ------------------------------------------------------------ onehot_matmul
@pytest.mark.parametrize("n,r,d", [
    (8, 16, 8), (128, 512, 128), (130, 513, 129), (1, 7, 3), (256, 64, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_onehot_matmul_shapes(n, r, d, dtype):
    rng = np.random.default_rng(n * 1000 + r + d)
    idx = rng.integers(-2, r + 2, size=n).astype(np.int32)  # incl. OOR
    tbl = rng.normal(size=(r, d)).astype(np.float32)
    got = np.asarray(onehot_matmul(jnp.asarray(idx),
                                   jnp.asarray(tbl, dtype),
                                   block_n=8, block_r=16, block_d=128,
                                   interpret=True))
    want = np.asarray(onehot_matmul_ref(jnp.asarray(idx),
                                        jnp.asarray(tbl, dtype)))
    rtol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(got, want, rtol=rtol, atol=1e-5)


if HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 2), st.integers(1, 70), st.integers(1, 90),
           st.integers(1, 50))
    def test_onehot_matmul_property(seed, n, r, d):
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, r, size=n).astype(np.int32)
        tbl = rng.normal(size=(r, d)).astype(np.float32)
        got = np.asarray(onehot_matmul(jnp.asarray(idx), jnp.asarray(tbl),
                                       block_n=8, block_r=8, block_d=128,
                                       interpret=True))
        np.testing.assert_allclose(got, tbl[idx], rtol=1e-6, atol=1e-6)
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                             "(requirements-dev)")
    def test_onehot_matmul_property():
        pass


# --------------------------------------------------------- fused_star_gather
@pytest.mark.parametrize("n,l,rows", [
    (16, 8, (32, 16, 8)), (7, 130, (5, 9)), (64, 1, (100,)),
    (33, 257, (12, 7, 5, 3)),
])
def test_fused_star_gather_linear(n, l, rows):
    rng = np.random.default_rng(n + l)
    tables = [jnp.asarray(rng.normal(size=(r, l)).astype(np.float32))
              for r in rows]
    ptrs = jnp.asarray(
        np.stack([rng.integers(0, r, size=n) for r in rows]).astype(np.int32))
    found = jnp.asarray(rng.integers(0, 2, size=(len(rows), n)).astype(np.int32))
    got = np.asarray(fused_star_gather(ptrs, found, tables, interpret=True))
    want = np.asarray(fused_star_gather_ref(ptrs, found, tables))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_fused_star_gather_tree_compare():
    rng = np.random.default_rng(0)
    n, l, rows = 24, 16, (10, 8)
    # Integer-valued partials so == compare is exact.
    tables = [jnp.asarray(rng.integers(0, 3, size=(r, l)).astype(np.float32))
              for r in rows]
    h = jnp.asarray(rng.integers(0, 5, size=l).astype(np.float32))
    ptrs = jnp.asarray(
        np.stack([rng.integers(0, r, size=n) for r in rows]).astype(np.int32))
    found = jnp.asarray(np.ones((2, n), np.int32))
    got = np.asarray(fused_star_gather(ptrs, found, tables, h, interpret=True))
    want = np.asarray(fused_star_gather_ref(ptrs, found, tables, h))
    np.testing.assert_array_equal(got, want)
    assert set(np.unique(got)) <= {0.0, 1.0}


@pytest.mark.parametrize("l", [1, 5, 127, 130])
def test_fused_star_gather_nan_padded_columns_never_leak(l):
    """Regression: the wrapper NaN-pads ``h`` to the 128-lane multiple for
    the compare path; for every l % 128 != 0 the padded columns must not
    leak into the sliced result (no NaNs, no spurious leaf hits)."""
    rng = np.random.default_rng(l)
    n, rows = 33, (9, 6)
    # Integer-valued partials: rows summing to 0 would match a zero-padded
    # h in the pad columns — the NaN padding is what keeps them False.
    tables = [jnp.asarray(rng.integers(0, 2, size=(r, l)).astype(np.float32))
              for r in rows]
    h = jnp.asarray(rng.integers(0, 3, size=l).astype(np.float32))
    ptrs = jnp.asarray(
        np.stack([rng.integers(0, r, size=n) for r in rows]).astype(np.int32))
    found = jnp.asarray(rng.integers(0, 2, size=(2, n)).astype(np.int32))
    got = np.asarray(fused_star_gather(ptrs, found, tables, h, interpret=True))
    assert got.shape == (n, l)
    assert np.isfinite(got).all()
    assert set(np.unique(got)) <= {0.0, 1.0}
    want = np.asarray(fused_star_gather_ref(ptrs, found, tables, h))
    np.testing.assert_array_equal(got, want)


def test_fused_star_gather_empty_batch():
    """Regression: n == 0 must short-circuit (a zero-size Pallas grid is
    rejected) and preserve the (0, l) result shape, compare path or not."""
    rng = np.random.default_rng(0)
    l, rows = 5, (7, 3)
    tables = [jnp.asarray(rng.normal(size=(r, l)).astype(np.float32))
              for r in rows]
    ptrs = jnp.zeros((2, 0), jnp.int32)
    found = jnp.zeros((2, 0), jnp.int32)
    out = fused_star_gather(ptrs, found, tables, interpret=True)
    assert out.shape == (0, l)
    h = jnp.zeros((l,), jnp.float32)
    out = fused_star_gather(ptrs, found, tables, h, interpret=True)
    assert out.shape == (0, l)
    assert out.dtype == jnp.float32


# --------------------------------------------------------------- tree_predict
@pytest.mark.parametrize("n,k,depth", [(8, 4, 2), (130, 16, 4), (64, 256, 6),
                                       (17, 3, 1)])
def test_tree_predict_kernel_vs_ref(n, k, depth):
    rng = np.random.default_rng(n + k + depth)
    tree = random_tree(rng, k, depth)
    x = rng.normal(size=(n, k)).astype(np.float32)
    got = np.asarray(tree_predict(jnp.asarray(x), tree.F, tree.v, tree.H,
                                  tree.h, block_n=8, block_l=128,
                                  interpret=True))
    want = np.asarray(tree_predict_ref(jnp.asarray(x), tree.F, tree.v,
                                       tree.H, tree.h))
    np.testing.assert_array_equal(got, want)
    # Exactly one leaf fires per row.
    np.testing.assert_array_equal(got.sum(axis=1), np.ones(n))


def test_tree_predict_kernel_equals_model_apply():
    rng = np.random.default_rng(5)
    tree = random_tree(rng, 12, 3)
    x = jnp.asarray(rng.normal(size=(40, 12)).astype(np.float32))
    got = np.asarray(tree_predict(x, tree.F, tree.v, tree.H, tree.h,
                                  block_n=8, interpret=True))
    want = np.asarray(tree.apply(x))
    np.testing.assert_array_equal(got, want)
