"""Rewrite-engine tests: every rule must be bit-exact vs the unrewritten
plan across fused/nonfused × segment/matmul, the trail must surface in
``plan.reason`` / ``explain()``, and the satellites — hop-level pooled
chains, the flat baseline's sub-dimension group keys — must hold their
sharing/exactness contracts.
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.fusion.operators import LinearOperator, tree_from_arrays
from repro.core.laq import Catalog, Table
from repro.core.laq.selection import Pred
from repro.core.query import (Aggregate, ArmSpec, ArtifactPool, ChainLink,
                              GroupKey, PredictionFilter, PredictiveQuery,
                              Session, compile_query, compile_serving,
                              rewrite_query)
from repro.core.fusion.operators import DecisionTreeGEMM
from repro.core.query.ir import PREDICTION
from repro.core.query.multiquery import join_key
from repro.core.query.rewrite import (RewriteResult, _col_bounds,
                                      feature_sites)
from repro.core.query.workload import _compare, np_oracle

COMBOS = [(b, a) for b in ("fused", "nonfused")
          for a in ("segment", "matmul")]


# --------------------------------------------------------------------------
# Schema: one star dimension with three features, integer-valued
# --------------------------------------------------------------------------
def _star_tables(seed=0, n=48):
    rng = np.random.default_rng(seed)
    d = Table.from_columns("d", {
        "d_pk": np.arange(8),
        "d_f0": rng.integers(-4, 5, 8),
        "d_f1": rng.integers(-4, 5, 8),
        "d_f2": rng.integers(-4, 5, 8)},
        key_cols=("d_pk",), capacity=16)
    fact = Table.from_columns("f", {
        "fk": rng.integers(0, 10, n),          # some FK misses
        "f_g": rng.integers(0, 3, n),
        "m": rng.integers(-4, 5, n)},
        key_cols=("fk", "f_g"), capacity=64)
    return {"d": d, "f": fact}


def _tree():
    # node0: f0 > 0; node1: f1 > 1; node2: f0 > -1.  Leaf 3 (right-right)
    # ⟺ f0 > 0 ∧ f0 > -1 ⟺ d_f0 > 0 — a single distilled predicate.
    return tree_from_arrays(np.array([0, 1, 0]),
                            np.array([0., 1., -1.], np.float32), 3)


def _q(model, *, model_preds=(), arm_preds=(), aggs=None, groups=True):
    arm = ArmSpec("d", "fk", "d_pk", ("d_f0", "d_f1", "d_f2"),
                  tuple(arm_preds))
    if aggs is None:
        aggs = (Aggregate("m", "sum", "rev"), Aggregate("*", "count", "n"))
    gks = (GroupKey("fact", "f_g", 3),) if groups else ()
    return PredictiveQuery("f", (arm,), (), model, gks, tuple(aggs),
                           3 if groups else 8, model_preds=tuple(model_preds))


def _check_on_off(tables, q, rule, extra=()):
    """Compile with rewrite on and off across every combo; both must match
    the float64 oracle bit-exactly, and ``rule`` must appear in the trail."""
    want = np_oracle(tables, q)
    for backend, agg_backend in COMBOS:
        on = compile_query(Catalog(dict(tables)), q, backend=backend,
                           agg_backend=agg_backend)
        off = compile_query(Catalog(dict(tables)), q, backend=backend,
                            agg_backend=agg_backend, rewrite="off")
        assert any(rule in t for t in on._rewrites), on._rewrites
        for name in (rule, *extra):
            assert name in on.plan.reason
        assert off._rewrites == ()
        assert "rewrite=[" not in off.plan.reason
        lbl = f"{backend}/{agg_backend}"
        assert _compare(on.run(), want, q, f"on {lbl}") == []
        assert _compare(off.run(), want, q, f"off {lbl}") == []
    return on


# --------------------------------------------------------------------------
# Rule 2: tree→predicate distillation
# --------------------------------------------------------------------------
def test_distill_single_leaf_drops_model():
    tables = _star_tables()
    q = _q(_tree(), model_preds=[PredictionFilter(3, "==", 1.0)])
    plan = _check_on_off(tables, q, "distill_tree_filter",
                         extra=("model dropped",))
    # The rewritten IR is a pure relational query: model gone, the leaf's
    # path compiled into one dimension predicate, features dropped.
    rw = rewrite_query(tables, q)
    assert isinstance(rw, RewriteResult) and rw.changed
    assert rw.query.model is None and rw.query.model_preds == ()
    assert rw.query.arms[0].feature_cols == ()
    preds = rw.query.arms[0].preds
    assert [(p.col, p.op, p.value) for p in preds] == [("d_f0", ">", 0.0)]
    # explain() surfaces the trail.
    rep = plan.explain()
    assert dict(rep.extras)["rewrites"] == plan._rewrites


def test_distill_vacuous_filter_dropped():
    tables = _star_tables(1)
    # >= 0 holds for every one-hot output: the filter is vacuous.
    q = _q(_tree(), model_preds=[PredictionFilter(0, ">=", 0.0)],
           aggs=(Aggregate(PREDICTION, "sum", "p"),
                 Aggregate("*", "count", "n")))
    rw = rewrite_query(tables, q)
    assert rw.query.model_preds == () and rw.query.model is not None
    assert any("vacuous" in t for t in rw.trail)
    _check_on_off(tables, q, "distill_tree_filter")


def test_distill_blocked_by_prediction_aggregate():
    tables = _star_tables(2)
    q = _q(_tree(), model_preds=[PredictionFilter(3, "==", 1.0)],
           aggs=(Aggregate(PREDICTION, "sum", "p"),))
    rw = rewrite_query(tables, q)
    # Predictions still feed an aggregate: the model must stay.
    assert rw.query.model is not None
    want = np_oracle(tables, q)
    res = compile_query(Catalog(dict(tables)), q).run()
    assert _compare(res, want, q, "pred-agg") == []


def test_distill_multi_leaf_not_expressible():
    tables = _star_tables(3)
    # != selects 3 of 4 leaves — an OR of paths; the rule must refuse.
    q = _q(_tree(), model_preds=[PredictionFilter(3, "!=", 1.0)])
    rw = rewrite_query(tables, q)
    assert rw.query.model is not None
    want = np_oracle(tables, q)
    res = compile_query(Catalog(dict(tables)), q).run()
    assert _compare(res, want, q, "multi-leaf") == []


# --------------------------------------------------------------------------
# Rule 1: constant-input folding (+ rule 4 riding along)
# --------------------------------------------------------------------------
def test_fold_constants_into_bias():
    tables = _star_tables(4)
    model = LinearOperator(jnp.asarray([[2., 1.], [0., 0.], [3., -1.]],
                                       jnp.float32))
    q = _q(model, arm_preds=[Pred("d_f0", "==", 2)],
           aggs=(Aggregate(PREDICTION, "sum", "p"),
                 Aggregate("*", "count", "n")))
    plan = _check_on_off(tables, q, "fold_constant_inputs")
    rw = rewrite_query(tables, q)
    # d_f0 pinned to 2 → bias 2·[2,1] = [4,2]; d_f1's zero row projected.
    assert any("project_zero_weights" in t for t in rw.trail)
    m = rw.query.model
    np.testing.assert_array_equal(np.asarray(m.bias), [4., 2.])
    assert m.L.shape == (1, 2)
    assert rw.query.arms[0].feature_cols == ("d_f2",)
    assert any("fold_constant_inputs" in t for t in plan._rewrites)


def test_fold_keeps_at_least_one_feature():
    tables = _star_tables(5)
    model = LinearOperator(jnp.asarray([[2.]], jnp.float32))
    arm = ArmSpec("d", "fk", "d_pk", ("d_f0",), (Pred("d_f0", "==", 1),))
    q = PredictiveQuery("f", (arm,), (), model, (),
                        (Aggregate(PREDICTION, "sum", "p"),), 8)
    rw = rewrite_query(tables, q)
    # Pinning the only feature would leave an empty model: refuse.
    assert not any("fold" in t for t in rw.trail)
    want = np_oracle(tables, q)
    res = compile_query(Catalog(dict(tables)), q).run()
    assert _compare(res, want, q, "single-feature") == []


# --------------------------------------------------------------------------
# Rule 3: predicate-implied tree pruning
# --------------------------------------------------------------------------
def test_prune_tree_branches():
    tables = _star_tables(6)
    # d_f0 > 2 decides node0 (f0>0) and node2 (f0>-1) True; only node1
    # (f1 > 1) survives, then the dead f0/f2 rows project out.
    q = _q(_tree(), arm_preds=[Pred("d_f0", ">", 2)],
           aggs=(Aggregate(PREDICTION, "sum", "p"),
                 Aggregate("*", "count", "n")))
    plan = _check_on_off(tables, q, "prune_tree_branches")
    rw = rewrite_query(tables, q)
    assert any("3->1 nodes" in t for t in rw.trail)
    assert any("project_zero_weights" in t for t in rw.trail)
    m = rw.query.model
    assert m.F.shape[1] == 1 and rw.query.arms[0].feature_cols == ("d_f1",)
    assert plan._rewrites


# --------------------------------------------------------------------------
# Interval analysis: stacked predicates on one column (strictness merging)
# --------------------------------------------------------------------------
def test_col_bounds_between_clears_stale_strictness():
    # 'between' after '>' replaces the strict lo=2 with a NON-strict lo=6:
    # x=6 satisfies both predicates, so `x > 6` must stay undecided.
    b = _col_bounds([Pred("x", ">", 2), Pred("x", "between", (6, 10))], "x")
    assert (b.lo, b.lo_strict, b.hi, b.hi_strict) == (6.0, False, 10.0,
                                                      False)
    assert b.forced(np.float32(6.0)) is None
    assert b.forced(np.float32(5.0)) is True
    assert b.forced(np.float32(10.0)) is False


def test_col_bounds_le_clears_stale_lt_strictness():
    # '<=' tightening past a strict '<' must clear hi_strict: x may be 8,
    # so the finite domain {5, 8} is not pinned to a single value.
    b = _col_bounds([Pred("x", "<", 10), Pred("x", "<=", 8),
                     Pred("x", "in", (5, 8))], "x")
    assert (b.hi, b.hi_strict) == (8.0, False)
    assert b.pinned() is None


def test_col_bounds_strictness_kept_at_equal_value():
    # A strict bound at the same value is the tighter one either way round.
    for preds in ([Pred("x", ">", 6), Pred("x", "between", (6, 10))],
                  [Pred("x", "between", (6, 10)), Pred("x", ">", 6)]):
        b = _col_bounds(preds, "x")
        assert b.lo_strict and b.forced(np.float32(6.0)) is True
    b = _col_bounds([Pred("x", "<", 8), Pred("x", "between", (0, 8))], "x")
    assert b.hi_strict


def test_col_bounds_pin_via_stacked_inequalities():
    b = _col_bounds([Pred("x", ">=", 2), Pred("x", "<=", 2)], "x")
    assert b.pinned() == np.float32(2.0)
    # A strict bound at the pin value empties the interval — no pin.
    b = _col_bounds([Pred("x", ">", 2), Pred("x", "<=", 2)], "x")
    assert b.pinned() is None


def test_prune_keeps_boundary_node_under_stacked_preds():
    # Regression: [d_f0 > -3, d_f0 between (0, 4)] admits d_f0 == 0, which
    # takes node0's (f0 > 0) *left* branch; a stale strict flag from '>'
    # used to decide the node True and misroute exactly those rows.
    tables = _star_tables(16)
    q = _q(_tree(), arm_preds=[Pred("d_f0", ">", -3),
                               Pred("d_f0", "between", (0, 4))],
           aggs=(Aggregate(PREDICTION, "sum", "p"),
                 Aggregate("*", "count", "n")))
    _check_on_off(tables, q, "prune_tree_branches")
    rw = rewrite_query(tables, q)
    # node2 (f0 > -1) is decided; node0 (f0 > 0) must survive.
    assert any("3->2 nodes" in t for t in rw.trail)


def test_fold_refuses_false_pin_from_stale_strictness():
    # Regression: [d_f0 < 4, d_f0 <= 2, d_f0 in (0, 2)] leaves BOTH 0 and
    # 2 feasible; the stale '<' flag used to exclude 2 and fold 0 into the
    # bias, corrupting every surviving d_f0 == 2 row.
    tables = _star_tables(15)
    model = LinearOperator(jnp.asarray([[2., 1.], [1., 2.], [3., -1.]],
                                       jnp.float32))
    q = _q(model, arm_preds=[Pred("d_f0", "<", 4), Pred("d_f0", "<=", 2),
                             Pred("d_f0", "in", (0, 2))],
           aggs=(Aggregate(PREDICTION, "sum", "p"),
                 Aggregate("*", "count", "n")))
    rw = rewrite_query(tables, q)
    assert not any("fold_constant_inputs" in t for t in rw.trail)
    want = np_oracle(tables, q)
    on = compile_query(Catalog(dict(tables)), q).run()
    off = compile_query(Catalog(dict(tables)), q, rewrite="off").run()
    assert _compare(on, want, q, "on") == []
    assert _compare(off, want, q, "off") == []


def test_fold_pins_via_stacked_inequalities():
    # >= 2 and <= 2 together pin d_f0 without an equality predicate.
    tables = _star_tables(17)
    model = LinearOperator(jnp.asarray([[2., 1.], [1., 2.], [3., -1.]],
                                       jnp.float32))
    q = _q(model, arm_preds=[Pred("d_f0", ">=", 2), Pred("d_f0", "<=", 2)],
           aggs=(Aggregate(PREDICTION, "sum", "p"),
                 Aggregate("*", "count", "n")))
    _check_on_off(tables, q, "fold_constant_inputs")
    rw = rewrite_query(tables, q)
    m = rw.query.model
    np.testing.assert_array_equal(np.asarray(m.bias), [4., 2.])
    assert rw.query.arms[0].feature_cols == ("d_f1", "d_f2")


def test_malformed_multi_feature_node_refused():
    # An F column with two 1s (a sum-of-features node) violates the
    # one-1-per-column invariant: distill must refuse and prune must skip
    # that node rather than treat it as testing only the argmax feature.
    tables = _star_tables(14)
    t = _tree()
    F = np.asarray(t.F).copy()
    F[2, 0] = 1.0                      # node0 now tests d_f0 + d_f2
    m = DecisionTreeGEMM(jnp.asarray(F), t.v, t.H, t.h)
    q = _q(m, model_preds=[PredictionFilter(3, "==", 1.0)])
    rw = rewrite_query(tables, q)
    assert rw.query.model is not None          # distill refused
    assert not rw.changed
    want = np_oracle(tables, q)
    on = compile_query(Catalog(dict(tables)), q).run()
    off = compile_query(Catalog(dict(tables)), q, rewrite="off").run()
    assert _compare(on, want, q, "malformed-on") == []
    assert _compare(off, want, q, "malformed-off") == []
    # Pruning skips the malformed node but still fires on sound ones.
    q2 = _q(m, arm_preds=[Pred("d_f0", ">", 2)],
            aggs=(Aggregate(PREDICTION, "sum", "p"),
                  Aggregate("*", "count", "n")))
    rw2 = rewrite_query(tables, q2)
    assert any("3->2 nodes" in s for s in rw2.trail)
    want2 = np_oracle(tables, q2)
    on2 = compile_query(Catalog(dict(tables)), q2).run()
    off2 = compile_query(Catalog(dict(tables)), q2, rewrite="off").run()
    assert _compare(on2, want2, q2, "prune-on") == []
    assert _compare(off2, want2, q2, "prune-off") == []


# --------------------------------------------------------------------------
# Engine plumbing: knob validation, session cache keys, serving, sites
# --------------------------------------------------------------------------
def test_rewrite_knob_validated():
    tables = _star_tables(7)
    q = _q(None, groups=True)
    with pytest.raises(ValueError, match="rewrite"):
        compile_query(Catalog(dict(tables)), q, rewrite="sometimes")


def test_key_columns_never_distilled():
    # A tree over a column that is also a key column must not rewrite:
    # Pred.mask compares the int key array, not the f32 feature.
    tables = _star_tables(8)
    rng = np.random.default_rng(8)
    d = Table.from_columns("d", {
        "d_pk": np.arange(8), "d_f0": rng.integers(-4, 5, 8)},
        key_cols=("d_pk", "d_f0"), capacity=16)
    tables = dict(tables, d=d)
    arm = ArmSpec("d", "fk", "d_pk", ("d_f0",), ())
    q = PredictiveQuery(
        "f", (arm,), (), tree_from_arrays(np.array([0]),
                                          np.array([0.], np.float32), 1),
        (), (Aggregate("m", "sum", "rev"),), 8,
        model_preds=(PredictionFilter(1, "==", 1.0),))
    rw = rewrite_query(tables, q)
    assert rw.query.model is not None


def test_session_cache_distinguishes_model_preds():
    tables = _star_tables(9)
    sess = Session(Catalog(dict(tables)))
    q0 = _q(_tree(), aggs=(Aggregate(PREDICTION, "sum", "p"),))
    q1 = dataclasses.replace(q0,
                             model_preds=(PredictionFilter(3, "==", 1.0),))
    p0, p1 = sess.compile(q0), sess.compile(q1)
    assert p0 is not p1
    assert sess.compile(q1) is p1          # cache hit on re-bind
    w0, w1 = np_oracle(tables, q0), np_oracle(tables, q1)
    assert _compare(p0.run(), w0, q0, "unfiltered") == []
    assert _compare(p1.run(), w1, q1, "filtered") == []


def test_builder_predict_where_and_refresh():
    tables = _star_tables(10)
    cat = Catalog(dict(tables))
    sess = Session(cat)
    plan = (sess.query("f")
            .join("d", on=("fk", "d_pk"),
                  features=["d_f0", "d_f1", "d_f2"])
            .predict(_tree(), where=[(3, "==", 1.0)])
            .group_by(("fact", "f_g", 3), num_groups=3)
            .agg(rev="sum(m)", n="count")
            .compile())
    assert any("distill" in t for t in plan._rewrites)
    snap = {n: cat[n] for n in cat}
    q = _q(_tree(), model_preds=[PredictionFilter(3, "==", 1.0)])
    assert _compare(plan.run(), np_oracle(snap, q), q, "builder") == []
    # Rewrites are data-independent: appends refresh through the same
    # delta paths and stay oracle-exact.
    rng = np.random.default_rng(10)
    cat.append("f", {"fk": rng.integers(0, 10, 4),
                     "f_g": rng.integers(0, 3, 4),
                     "m": rng.integers(-4, 5, 4)})
    plan.refresh()
    snap = {n: cat[n] for n in cat}
    assert _compare(plan.run(), np_oracle(snap, q), q, "refreshed") == []


def test_compile_serving_rejects_model_preds():
    tables = _star_tables(11)
    q = _q(_tree(), model_preds=[PredictionFilter(3, "==", 1.0)],
           groups=False)
    with pytest.raises(ValueError, match="model_preds"):
        compile_serving(Catalog(dict(tables)), q)


def test_feature_sites_global_order():
    arm0 = ArmSpec("d", "fk", "d_pk", ("d_f0",), (),
                   links=(ChainLink("e", "d_to_e", "e_pk", ("e_f0",)),))
    arm1 = ArmSpec("g", "fk2", "g_pk", ("g_f0",), ())
    q = PredictiveQuery("f", (arm0, arm1), (), None, (),
                        (Aggregate("m", "sum", "rev"),), 8)
    sites = feature_sites(q)
    assert [(s.table, s.col) for s in sites] == [
        ("d", "d_f0"), ("e", "e_f0"), ("g", "g_f0")]


# --------------------------------------------------------------------------
# Satellite: hop-level pooled chains
# --------------------------------------------------------------------------
def _chain_tables(seed=0, n=40):
    rng = np.random.default_rng(seed)
    e2 = Table.from_columns("e2", {
        "e2_pk": np.arange(4), "e2_f0": rng.integers(-4, 5, 4)},
        key_cols=("e2_pk",), capacity=8)
    e1 = Table.from_columns("e1", {
        "e1_pk": np.arange(6), "e1_to_e2": rng.integers(0, 6, 6),
        "e1_f0": rng.integers(-4, 5, 6)},
        key_cols=("e1_pk", "e1_to_e2"), capacity=12)
    d = Table.from_columns("d", {
        "d_pk": np.arange(8), "d_to_e1": rng.integers(0, 8, 8),
        "d_f0": rng.integers(-4, 5, 8)},
        key_cols=("d_pk", "d_to_e1"), capacity=16)
    fact = Table.from_columns("f", {
        "fk": rng.integers(0, 10, n), "f_g": rng.integers(0, 3, n),
        "m": rng.integers(-4, 5, n)},
        key_cols=("fk", "f_g"), capacity=64)
    return {"e2": e2, "e1": e1, "d": d, "f": fact}


def _chain_q(depth2: bool):
    links = (ChainLink("e1", "d_to_e1", "e1_pk", ("e1_f0",)),)
    feats = ["d_f0", "e1_f0"]
    if depth2:
        links += (ChainLink("e2", "e1_to_e2", "e2_pk", ("e2_f0",),
                            parent="e1"),)
        feats.append("e2_f0")
    arm = ArmSpec("d", "fk", "d_pk", ("d_f0",), (), links=links)
    model = LinearOperator(jnp.asarray(
        np.ones((len(feats), 1)), jnp.float32))
    return PredictiveQuery("f", (arm,), (), model, (),
                           (Aggregate(PREDICTION, "sum", "p"),
                            Aggregate("*", "count", "n")), 8)


def test_shared_hop_pooled_once_across_chains():
    tables = _chain_tables()
    cat = Catalog(dict(tables))
    pool = ArtifactPool(cat)
    q1, q2 = _chain_q(depth2=True), _chain_q(depth2=False)
    p1 = compile_query(cat, q1, pool=pool)
    p2 = compile_query(cat, q2, pool=pool)
    st = pool.stats()
    assert st["by_kind"].get("chain") == 2     # distinct chain contents
    # The d→e1 hop probe is ONE pooled entry, referenced by both chains.
    hop = join_key("d", "d_to_e1", "e1", "e1_pk")
    assert pool.refcount(hop) == 2
    # Results stay oracle-exact through the pooled-hop path.
    snap = {n: cat[n] for n in cat}
    assert _compare(p1.run(), np_oracle(snap, q1), q1, "hop-q1") == []
    assert _compare(p2.run(), np_oracle(snap, q2), q2, "hop-q2") == []
    # Appending to the deep link refreshes the shared hop exactly once.
    rng = np.random.default_rng(1)
    cat.append("e1", {"e1_pk": np.array([6, 7]),
                      "e1_to_e2": rng.integers(0, 6, 2),
                      "e1_f0": rng.integers(-4, 5, 2)})
    p1.refresh()
    p2.refresh()
    assert pool.update_count(hop) == 1
    snap = {n: cat[n] for n in cat}
    assert _compare(p1.run(), np_oracle(snap, q1), q1, "hop-q1r") == []
    assert _compare(p2.run(), np_oracle(snap, q2), q2, "hop-q2r") == []
    # Releasing both plans drops the chains AND their hop references.
    p1.close()
    p2.close()
    assert pool.stats()["entries"] == 0
