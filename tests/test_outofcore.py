"""Out-of-core fact streaming + tombstone deletes (ISSUE 8).

The contract under test:
  * the streamed program is **bit-exact** vs the in-core run of the same
    fused/gather/segment program for every chunk size — 1, non-divisors of
    the fact length, larger than the fact — on grouped aggregates and
    ungrouped count/min/max (the carried segment accumulator replays the
    exact adds of the one-shot fold; ungrouped sum/mean have no segment
    structure to carry, so they are allclose),
  * both agree with a float64 numpy oracle over the live rows,
  * a refresh that keeps capacity (appends + tombstone deletes) re-chunks
    with **zero retraces** — one trace per compiled plan, ever,
  * ``delete_rows`` is a pure validity fold (shapes/keys/placement kept;
    delta refresh ≡ cold rebuild across fused/nonfused × segment/matmul),
    and ``changed_spans`` reports deletions distinct from updates,
  * ``compact`` rewrites row ids and every referencing plan recompiles
    with a named reason,
  * the planner streams exactly when the fact working set exceeds the
    memory budget (or the caller pins a chunk size) and says why,
  * streaming composes with the session: pooled dimension-side artifacts
    are shared across chunks, plans opt out of ``run_all`` stacking.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import LinearOperator
from repro.core.laq import Catalog, ChangedSpans, Table, changed_spans
from repro.core.laq.selection import Pred
from repro.core.query import (PREDICTION, Aggregate, ArmSpec, GroupKey,
                              PredictiveQuery, Session, compile_query,
                              compile_serving, plan_chunk_rows,
                              plan_streaming)
from repro.core.query.multiquery import stack_key

#: The in-core baseline streaming must match bitwise.  Pinned explicitly:
#: the auto-planner may lower small-group aggregations via matmul — a
#: different (valid) program whose sums associate differently — while the
#: streamed program is always the fused/gather/segment lowering.
PINNED = dict(backend="fused", join_backend="gather", agg_backend="segment")


# --------------------------------------------------------------------- data
def star_catalog(seed: int, n_fact: int = 640, n_d1: int = 24, n_d2: int = 10,
                 slack: int = 16) -> Catalog:
    rng = np.random.default_rng(seed)
    d1 = {"pk": np.arange(n_d1) * 2,          # sparse keys: FKs can miss
          "a": rng.normal(size=n_d1), "b": rng.normal(size=n_d1)}
    d2 = {"pk2": np.arange(n_d2),
          "c": rng.normal(size=n_d2),
          "g": rng.integers(0, 4, n_d2)}
    f = {"fk1": rng.integers(0, 2 * (n_d1 + slack), n_fact),
         "fk2": rng.integers(0, n_d2 + slack // 2, n_fact),
         "val": rng.normal(size=n_fact)}
    return Catalog({
        "d1": Table.from_columns("d1", d1, key_cols=("pk",),
                                 capacity=n_d1 + slack),
        "d2": Table.from_columns("d2", d2, key_cols=("pk2", "g"),
                                 capacity=n_d2 + slack),
        "fact": Table.from_columns("fact", f, key_cols=("fk1", "fk2"),
                                   capacity=n_fact + slack),
    })


def _model(seed: int = 1) -> LinearOperator:
    rng = np.random.default_rng(seed)
    return LinearOperator(jnp.asarray(rng.normal(size=(3, 2)), jnp.float32))


def _query(model, *, group: bool = True,
           extra_aggs: bool = False) -> PredictiveQuery:
    gk = (GroupKey("d2", "g", 4),) if group else ()
    aggs = [Aggregate(PREDICTION, "sum", "pred"),
            Aggregate(PREDICTION, "mean", "pmean"),
            Aggregate("val", "mean", "v"),
            Aggregate("*", "count", "n")]
    if extra_aggs:
        aggs += [Aggregate("val", "min", "vmin"),
                 Aggregate("val", "max", "vmax"),
                 Aggregate(("mul", "val", "val"), "sum", "v2")]
    return PredictiveQuery(
        fact="fact",
        arms=(ArmSpec("d1", "fk1", "pk", ("a", "b"),
                      (Pred("a", ">", -1.0),)),
              ArmSpec("d2", "fk2", "pk2", ("c",))),
        fact_preds=(Pred("val", ">", -2.0),),
        model=model,
        group_keys=gk,
        aggregates=tuple(aggs),
        num_groups=4 if group else 8192)


def _assert_bitwise(got, want, keys):
    for k in keys:
        assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), k


# ------------------------------------------------------------- numpy oracle
def _oracle(cat: Catalog, model: LinearOperator, *, group: bool = True):
    """Float64 row-at-a-time evaluation of ``_query`` over the live rows."""
    fact, d1, d2 = cat["fact"], cat["d1"], cat["d2"]

    def live(t):
        m = np.arange(t.capacity) < int(t.nvalid)
        if t.deleted is not None:
            m &= ~np.asarray(t.deleted)
        return m

    def lookup(t, pk_col):
        alive = live(t)
        return {int(k): i for i, k in enumerate(np.asarray(t.key(pk_col)))
                if alive[i]}

    idx1, idx2 = lookup(d1, "pk"), lookup(d2, "pk2")
    a = np.asarray(d1.col("a"), np.float64)
    b = np.asarray(d1.col("b"), np.float64)
    c = np.asarray(d2.col("c"), np.float64)
    g = np.asarray(d2.col("g"), np.int64)
    val = np.asarray(fact.col("val"), np.float64)
    fk1 = np.asarray(fact.key("fk1"))
    fk2 = np.asarray(fact.key("fk2"))
    L = np.asarray(model.L, np.float64)
    G = 4 if group else 1
    sums = {k: np.zeros((G, 2) if k in ("pred", "pmean") else (G,))
            for k in ("pred", "pmean", "v")}
    count = np.zeros((G,))
    flive = live(fact)
    for i in range(int(fact.nvalid)):
        if not flive[i] or not val[i] > -2.0:
            continue
        j1, j2 = idx1.get(int(fk1[i])), idx2.get(int(fk2[i]))
        if j1 is None or j2 is None or not a[j1] > -1.0:
            continue
        gid = int(g[j2]) if group else 0
        x = np.array([a[j1], b[j1], c[j2]])
        sums["pred"][gid] += x @ L
        sums["pmean"][gid] += x @ L
        sums["v"][gid] += val[i]
        count[gid] += 1
    cnt = np.maximum(count, 1.0)
    out = {"pred": sums["pred"], "pmean": sums["pmean"] / cnt[:, None],
           "v": sums["v"] / cnt, "n": count}
    if not group:
        out = {k: v[0] for k, v in out.items()}
    return out


# ------------------------------------------------- streamed ≡ in-core ≡ oracle
@pytest.mark.parametrize("chunk", [1, 7, 64, 100, 999, 5000])
def test_grouped_stream_bitexact_chunk_sweep(chunk):
    """Every chunk size — 1, non-divisors, > fact rows — replays the exact
    in-core segment fold, including min/max and expression aggregates."""
    cat = star_catalog(0)
    model = _model()
    q = _query(model, extra_aggs=True)
    streamed = compile_query(cat, q, stream_chunk_rows=chunk)
    incore = compile_query(star_catalog(0), q, **PINNED)
    assert streamed._stream is not None
    _assert_bitwise(streamed.run(), incore.run(),
                    ("pred", "pmean", "v", "n", "vmin", "vmax", "v2"))


@pytest.mark.parametrize("chunk", [1, 100, 5000])
def test_ungrouped_stream(chunk):
    """Ungrouped count/min/max are bitwise; sum/mean fold per-chunk scalar
    partials (no segment structure to carry) and are allclose."""
    cat = star_catalog(3)
    model = _model()
    q = _query(model, group=False, extra_aggs=True)
    streamed = compile_query(cat, q, stream_chunk_rows=chunk).run()
    incore = compile_query(star_catalog(3), q, **PINNED).run()
    _assert_bitwise(streamed, incore, ("n", "vmin", "vmax"))
    for k in ("pred", "pmean", "v", "v2"):
        np.testing.assert_allclose(np.asarray(streamed[k]),
                                   np.asarray(incore[k]), rtol=1e-5)


@pytest.mark.parametrize("group", [True, False])
def test_stream_matches_numpy_oracle(group):
    cat = star_catalog(5)
    model = _model()
    cat.delete_rows("fact", [0, 3, 100, 639])
    cat.delete_rows("d1", [2, 9])
    got = compile_query(cat, _query(model, group=group),
                        stream_chunk_rows=97).run()
    want = _oracle(cat, model, group=group)
    for k in ("pred", "pmean", "v", "n"):
        np.testing.assert_allclose(np.asarray(got[k]), want[k],
                                   rtol=1e-5, atol=1e-6)


def test_stream_refresh_zero_retrace_and_bitexact():
    """Append + delete within capacity: the executor re-chunks the same
    shapes, so the single chunk-step trace is reused — and the refreshed
    stream equals a cold rebuild bitwise."""
    rng = np.random.default_rng(11)
    cat = star_catalog(7)
    model = _model()
    q = _query(model, extra_aggs=True)
    streamed = compile_query(cat, q, stream_chunk_rows=128)
    streamed.run()
    traces0 = streamed._stream.traces
    assert traces0 >= 1
    cat.append("fact", {"fk1": rng.integers(0, 80, 8),
                        "fk2": rng.integers(0, 18, 8),
                        "val": rng.normal(size=8)})
    cat.delete_rows("fact", [5, 77, 400, 641])
    cat.delete_rows("d1", [1, 4])
    note = streamed.refresh()
    assert "delta" in note
    cold = compile_query(cat, q, stream_chunk_rows=128)
    _assert_bitwise(streamed.run(), cold.run(),
                    ("pred", "pmean", "v", "n", "vmin", "vmax", "v2"))
    assert streamed._stream.traces == traces0, "chunk step retraced"


def test_compact_recompiles_with_named_reason():
    cat = star_catalog(9)
    model = _model()
    q = _query(model)
    streamed = compile_query(cat, q, stream_chunk_rows=64)
    streamed.run()
    cat.delete_rows("fact", np.arange(0, 400, 2))
    assert cat.compact("fact")
    note = streamed.refresh()
    assert "compaction:fact" in note
    _assert_bitwise(streamed.run(),
                    compile_query(cat, q, stream_chunk_rows=64).run(),
                    ("pred", "v", "n"))


# ----------------------------------------------------------- planner choice
def test_memory_budget_drives_streaming():
    cat = star_catalog(0)
    q = _query(_model())
    small = compile_query(cat, q, memory_budget_bytes=20_000)
    assert small._stream is not None
    assert "stream=" in small.plan.reason
    big = compile_query(cat, q, memory_budget_bytes=10**9)
    assert big._stream is None
    assert "stream=off" in big.plan.reason
    _assert_bitwise(small.run(),
                    compile_query(cat, q, **PINNED).run(),
                    ("pred", "v", "n"))


def test_plan_chunk_rows_unit():
    # pinned / auto / off
    assert plan_chunk_rows(64, 1000, 100, None) == 64
    assert plan_chunk_rows(None, 1000, 100, None) is None
    assert plan_chunk_rows(None, 1000, 100, 10**9) is None   # fits: in-core
    assert plan_chunk_rows(None, 1000, 100, 20_000) == 200   # exceeds: auto
    assert plan_chunk_rows("auto", 1000, 100, 20_000) == 200
    assert 1 <= plan_chunk_rows("auto", 1000, 100, 1) <= 1000  # clamps
    assert plan_chunk_rows(0, 1000, 100, None) is None         # 0 ≡ off
    with pytest.raises(ValueError):
        plan_chunk_rows(-1, 1000, 100, None)
    on, why = plan_streaming(64, 1000, 100, None)
    assert on == 64 and "stream=" in why


def test_stream_rejects_incompatible_backends():
    cat = star_catalog(0)
    q = _query(_model())
    for bad in (dict(backend="nonfused"), dict(join_backend="matmul"),
                dict(agg_backend="matmul")):
        with pytest.raises(ValueError, match="stream"):
            compile_query(cat, q, stream_chunk_rows=64, **bad)

    def traced(rows):
        c = star_catalog(0)
        qq = dataclasses.replace(
            q, fact_preds=(Pred("val", ">", rows),))
        return compile_query(c, qq, stream_chunk_rows=64).run()["n"]

    with pytest.raises(ValueError, match="stream"):
        jax.jit(traced)(jnp.float32(-2.0))


# -------------------------------------------------------- session composure
def test_session_stream_knob_and_explain():
    cat = star_catalog(0)
    sess = Session(cat, stream_chunk_rows=100)
    q = _query(_model())
    c = sess.compile(q)
    assert c._stream is not None
    report = c.explain().as_dict()
    assert report["extras"]["stream"].startswith("stream:")
    assert "stream=" in report["plan_reason"]
    # streaming plans never stack — run_all falls back to per-plan run()
    assert stack_key(c) is None
    base = compile_query(star_catalog(0), q, **PINNED).run()
    for out in (c.run(), sess.run_all([q])[0]):
        _assert_bitwise(out, base, ("pred", "v", "n"))


def test_pooled_artifacts_are_dimension_side_and_shared():
    """The pool invariant streaming relies on: every pooled artifact a
    streaming plan holds is dimension-sided (chunking never slices it), so
    two plans sharing arms share them across chunk loops too."""
    cat = star_catalog(0)
    sess = Session(cat, stream_chunk_rows=64)
    model = _model()
    c1 = sess.compile(_query(model))
    c2 = sess.compile(_query(model, extra_aggs=True))
    assert c1 is not c2 and c1._stream is not None
    shared = set(c1._pool_keys()) & set(c2._pool_keys())
    assert any(k[0] == "partial" for k in shared)
    assert any(k[0] == "join" for k in shared)


# --------------------------------------------- deletion as a validity fold
def test_changed_spans_reports_deletes_distinct_from_updates():
    cat = star_catalog(0)
    v0 = cat.version("fact")
    cat.update_column("fact", "val", [3, 5], [1.0, 2.0])
    cat.delete_rows("fact", [5, 9])
    cs = changed_spans(cat.deltas_since("fact", v0))
    assert isinstance(cs, ChangedSpans)
    assert cs.span is None and not cs.grew
    assert cs.dirty == (3, 5) and cs.deleted == (5, 9)
    # bulk deletes log a covering span that expands at refresh time
    big = cat.delete_rows("fact", np.arange(100, 400))
    cs2 = changed_spans(cat.deltas_since("fact", big - 1))
    assert set(cs2.deleted) == set(range(100, 400))


def test_delete_rows_semantics():
    cat = star_catalog(0)
    t0 = cat["fact"]
    v = cat.delete_rows("fact", [0, 0, 5])
    t = cat["fact"]
    assert t.num_deleted == 2 and t.num_live == int(t.nvalid) - 2
    assert not bool(t.valid_mask()[0]) and bool(t.valid_mask()[1])
    # placement/shapes/keys untouched: pure validity fold
    assert t.capacity == t0.capacity and int(t.nvalid) == int(t0.nvalid)
    assert np.array_equal(np.asarray(t.key("fk1")),
                          np.asarray(t0.key("fk1")))
    assert cat.delete_rows("fact", [5]) == v        # re-delete: version no-op
    assert cat.tombstone_fraction("fact") == 2 / 640
    for bad in ([-1], [640]):
        with pytest.raises(ValueError):
            cat.delete_rows("fact", bad)
    assert not cat.compact("fact")                  # below threshold: no-op


@pytest.mark.parametrize("backend", ["fused", "nonfused"])
@pytest.mark.parametrize("agg_backend", ["segment", "matmul"])
def test_refresh_after_delete_equals_cold_rebuild(backend, agg_backend):
    """The satellite bugfix: the delta path treats deletions as mask-only
    scatters on every backend pair, matching a cold rebuild bitwise."""
    cat = star_catalog(21, n_fact=256)
    model = _model()
    q = _query(model, extra_aggs=True)
    plan = compile_query(cat, q, backend=backend, agg_backend=agg_backend)
    plan.run()
    cat.delete_rows("fact", [0, 17, 130, 255])
    cat.delete_rows("d1", [3, 8])
    cat.delete_rows("d2", [6])
    note = plan.refresh()
    assert "delta" in note
    cold = compile_query(cat, q, backend=backend, agg_backend=agg_backend)
    _assert_bitwise(plan.run(), cold.run(),
                    ("pred", "pmean", "v", "n", "vmin", "vmax", "v2"))


def test_serving_refresh_after_delete_equals_cold():
    cat = star_catalog(13)
    q = _query(_model())
    sess = Session(cat)
    rt = sess.serving(q, buckets=(8, 32))
    rng = np.random.default_rng(2)
    batch = {"fk1": jnp.asarray(rng.integers(0, 48, 20), jnp.int32),
             "fk2": jnp.asarray(rng.integers(0, 10, 20), jnp.int32)}
    rt.serve(batch)
    n0 = rt.num_compiles
    cat.delete_rows("d1", [2, 5, 11])
    cat.delete_rows("d2", [0, 7])
    sess.refresh()
    got = rt.serve(batch)
    want = compile_serving(cat, q, buckets=(8, 32)).serve(batch)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert rt.num_compiles == n0


# ----------------------------------------------------- the property sweep
def _equivalence_case(seed: int, chunk: int, ops: list):
    """One randomized append/delete interleaving: streamed ≡ in-core
    (bitwise) ≡ numpy oracle (allclose) after every mutation batch."""
    rng = np.random.default_rng(seed)
    cat = star_catalog(seed)
    model = _model()
    q = _query(model)
    streamed = compile_query(cat, q, stream_chunk_rows=chunk)
    for kind, arg in ops:
        if kind == "append":
            cat.append("fact", {"fk1": rng.integers(0, 80, arg),
                                "fk2": rng.integers(0, 18, arg),
                                "val": rng.normal(size=arg)})
        elif kind == "delete_fact":
            ids = rng.choice(int(cat["fact"].nvalid), size=arg,
                             replace=False)
            cat.delete_rows("fact", ids)
        else:
            ids = rng.choice(int(cat[kind].nvalid),
                             size=min(arg, 3), replace=False)
            cat.delete_rows(kind, ids)
        streamed.refresh()
        got = streamed.run()
        incore = compile_query(cat, q, **PINNED).run()
        _assert_bitwise(got, incore, ("pred", "pmean", "v", "n"))
        want = _oracle(cat, model)
        for k in ("pred", "v", "n"):
            np.testing.assert_allclose(np.asarray(got[k]), want[k],
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed,chunk,ops", [
    (0, 1, [("delete_fact", 5), ("append", 4)]),
    (1, 93, [("append", 6), ("delete_fact", 40), ("d1", 2)]),
    (2, 640, [("d2", 1), ("delete_fact", 10), ("append", 10),
              ("delete_fact", 30)]),
    (3, 5000, [("append", 16), ("d1", 3), ("d2", 2),
               ("delete_fact", 100)]),
])
def test_append_delete_interleavings(seed, chunk, ops):
    _equivalence_case(seed, chunk, ops)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # requirements-dev
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("append"), st.integers(1, 8)),
        st.tuples(st.just("delete_fact"), st.integers(1, 60)),
        st.tuples(st.just("d1"), st.integers(1, 3)),
        st.tuples(st.just("d2"), st.integers(1, 2)))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16),
           chunk=st.one_of(st.integers(1, 700), st.just(10_000)),
           ops=st.lists(_op, min_size=1, max_size=4))
    def test_streaming_equivalence_property(seed, chunk, ops):
        """Random chunk sizes (1, non-divisors, > fact rows), random
        tombstone sets and append/delete interleavings never break the
        three-way equivalence."""
        _equivalence_case(seed, chunk, ops)
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                             "(requirements-dev)")
    def test_streaming_equivalence_property():
        pass


# ------------------------------------------------------------------- scale
@pytest.mark.slow
def test_stream_at_scale_under_budget():
    """A fact ~40x the memory budget streams in budget-sized chunks and
    still matches the pinned in-core program bitwise."""
    cat = star_catalog(0, n_fact=200_000, slack=64)
    q = _query(_model(), extra_aggs=True)
    streamed = compile_query(cat, q, memory_budget_bytes=256 * 1024)
    assert streamed._stream is not None
    assert streamed._stream.chunk_bytes() <= 256 * 1024
    incore = compile_query(cat, q, **PINNED)
    _assert_bitwise(streamed.run(), incore.run(),
                    ("pred", "pmean", "v", "n", "vmin", "vmax", "v2"))
